//! Router: the multi-tenant serving front-end — one engine thread, many
//! (model × plan) services.
//!
//! ```text
//! request threads ──► Router::score(ScoreRequest{key, …})
//!                        │ admission control (global + per-service quotas)
//!                        ▼
//!                per-service BatcherHandle ──► Batcher (size/deadline)
//!                        │ [batch, seq]
//!                        ▼
//!                ModelService (device-resident quantized weights)
//!                        │ channel
//!                        ▼
//!                EngineHandle ──► one engine thread (owns the PJRT client)
//! ```
//!
//! The router owns the engine thread and a registry of services keyed by
//! [`ServiceKey`] (model name + [`PlanRef`]): a uniform [`QuantSpec`] is
//! the degenerate one-entry plan, and full per-tensor [`QuantPlan`]s are
//! keyed by their stable content digest ([`Router::register_plan`]), so
//! two plans of one model serve side by side behind the one engine.
//! Services are prepared **lazily on first request**: the first
//! `score`/`score_batch` for an unseen key quantizes the registered
//! checkpoint per its plan, uploads the weights once (device-resident
//! under a per-service key prefix), and compiles the scoring executable —
//! concurrent first requests for the same key block on a single
//! preparation, and the artifact/code caches are shared, so e.g. `nf4@64`
//! and `af4@64` reuse one compiled `score_q64_*` executable.
//!
//! Shutdown contract: [`Router::shutdown`] (or drop) first stops every
//! batcher — each one flushes its in-flight batch and drains its queue
//! through the engine — and only then stops the engine thread, so draining
//! work never races device teardown. The drain and a shutting-down flag
//! are set under one `services` lock, so a preparation racing shutdown
//! either lands before the drain snapshot (and is torn down with it) or
//! fails with an explicit "shutting down" error — never a stranded
//! batcher.
//!
//! On top of plain routing sit the **fleet operations** (PR 10):
//!
//! - **Weighted rollout** ([`Router::set_rollout`]): a per-model
//!   [`RolloutPolicy`] deterministically splits traffic between plan arms
//!   ([`Router::score_rollout`]), with canary → promote / rollback
//!   transitions — including **auto-rollback** when the canary's p99 or
//!   error rate regresses past its
//!   [`crate::coordinator::rollout::CanaryGuard`] relative to the
//!   baseline arms' live [`StageStat`]s. Every transition is logged and
//!   counted in `afq_rollout_transitions_total{action}`; transitions only
//!   re-point *future* assignments — in-flight requests always finish on
//!   the service that admitted them.
//! - **Device-residency budget** (`RouterConfig::device_budget_bytes`,
//!   env `AFQ_DEVICE_BUDGET_BYTES`): preparing a service first reserves
//!   its weight bytes against the budget, evicting least-recently-used
//!   idle services (their generation-tagged prefixes, via
//!   `Engine::evict`) until the reservation fits — **evict-before-upload,
//!   the budget never overshoots**. Evicted tenants re-prepare lazily on
//!   their next request; both sides are counted
//!   (`evictions`/`repreparations` in [`RouterSnapshot`]).
//! - **Background compilation** ([`Router::enable_compile_queue`]): a
//!   heterogeneous plan whose fused artifact was never AOT-compiled
//!   serves reconstructed-fp and submits a [`crate::coordinator::compile::CompileJob`];
//!   when the artifact lands, the router refreshes the manifest and
//!   **hot-swaps** the service onto the fused path — the slot flip is
//!   atomic under the services lock, the old instance drains gracefully,
//!   and `ServiceStat::artifact` flips observably with zero dropped or
//!   miscounted requests.
//!
//! Robustness: every router lock is taken through [`lock_sane`], which
//! recovers from mutex poisoning (a panicking holder — e.g. a panic
//! inside a preparation — must not turn every later request into a
//! panic) and counts recoveries in `afq_router_lock_poisoned_total`.

use crate::coordinator::batcher::{Batcher, BatcherConfig, BatcherHandle, ScoreBackend, ScoreResponse};
use crate::coordinator::compile::{CompileJob, CompileQueue, CompileWorker};
use crate::coordinator::engine_thread::{EngineHandle, EngineThread};
use crate::coordinator::rollout::{RolloutAction, RolloutPolicy};
use crate::coordinator::service::{ModelService, QuantSpec, ServePlan};
use crate::model::ParamSet;
use crate::plan::QuantPlan;
use crate::runtime::Manifest;
use crate::util::json::Json;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Lock a router mutex, recovering from poisoning instead of propagating
/// it. A panic inside a lock holder (a preparation, a test hook, a buggy
/// metric formatter) poisons the mutex; without recovery every later
/// request on that lock would panic too — one bad request would take the
/// whole fleet down. All router state guarded this way holds only
/// `Arc`-shared slots/registrations that are valid at every lock-release
/// point (inserts and removes are atomic under the guard), so the data is
/// safe to keep using. Recoveries are counted in
/// `afq_router_lock_poisoned_total` and logged.
fn lock_sane<'a, T>(m: &'a Mutex<T>, what: &'static str) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| {
        crate::obs::registry::counter("afq_router_lock_poisoned_total").inc(1);
        crate::log_warn!("router: recovered poisoned {what} lock");
        poisoned.into_inner()
    })
}

/// How a service key names its quantization configuration. Uniform specs
/// are the degenerate one-entry plan; full [`QuantPlan`]s are identified
/// by their **stable content digest** (see [`QuantPlan::digest`]), so two
/// distinct plans of one model are distinct tenants and re-registering an
/// identical plan lands on the same key.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum PlanRef {
    /// One spec for every tensor.
    Uniform(QuantSpec),
    /// A registered [`QuantPlan`], by content digest.
    Digest(String),
}

impl PlanRef {
    /// Display form: the spec label or `plan:<digest>`.
    pub fn label(&self) -> String {
        match self {
            PlanRef::Uniform(spec) => spec.label(),
            PlanRef::Digest(d) => format!("plan:{d}"),
        }
    }
}

/// Identifies one served configuration: which model, quantized per which
/// plan.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ServiceKey {
    pub model: String,
    pub plan: PlanRef,
}

impl ServiceKey {
    pub fn new(model: &str, spec: QuantSpec) -> ServiceKey {
        ServiceKey { model: model.to_string(), plan: PlanRef::Uniform(spec) }
    }

    /// Unquantized reference service for `model`.
    pub fn fp(model: &str) -> ServiceKey {
        Self::new(model, QuantSpec::fp())
    }

    /// Quantized service: `model` served as `family@block_size`.
    pub fn quant(model: &str, family: &str, block_size: usize) -> ServiceKey {
        Self::new(model, QuantSpec { family: family.to_string(), block_size })
    }

    /// Service for a per-tensor plan (register it via
    /// [`Router::register_plan`] — this only names the key).
    pub fn planned(plan: &QuantPlan) -> ServiceKey {
        ServiceKey { model: plan.model.clone(), plan: PlanRef::Digest(plan.digest().to_string()) }
    }

    /// The configuration half of the key (`nf4@64`, `fp`, `plan:<digest>`).
    pub fn config_label(&self) -> String {
        self.plan.label()
    }
}

impl std::fmt::Display for ServiceKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.model, self.plan.label())
    }
}

/// A routed request: the key names the service, the payload is one
/// sequence of exactly `seq` tokens (plus next-token targets). Every
/// request carries a process-unique span ID (allocated at construction)
/// that survives into [`ScoreResponse::trace`], so one request is one
/// identity across router, batcher, and engine accounting.
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    pub key: ServiceKey,
    pub span: u64,
    pub ids: Vec<i32>,
    pub targets: Vec<i32>,
}

impl ScoreRequest {
    pub fn new(key: &ServiceKey, ids: Vec<i32>, targets: Vec<i32>) -> ScoreRequest {
        ScoreRequest {
            key: key.clone(),
            span: crate::obs::trace::next_span_id(),
            ids,
            targets,
        }
    }
}

/// Router-wide serving policy.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Dynamic-batching deadline per service.
    pub max_wait: Duration,
    /// Per-service queue quota.
    pub service_queue: usize,
    /// Router-wide queue quota (sum of queued requests across services).
    pub global_queue: usize,
    /// Byte budget over engine-resident weight prefixes (`None` =
    /// unlimited). When preparing a service would overshoot, the router
    /// evicts least-recently-used idle services first — the budget is
    /// enforced *before* any bytes move, mirroring the panel cache's
    /// evict-before-insert contract. Defaults from
    /// `AFQ_DEVICE_BUDGET_BYTES` when set to a positive integer.
    pub device_budget_bytes: Option<u64>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        let device_budget_bytes = std::env::var("AFQ_DEVICE_BUDGET_BYTES")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .filter(|&b| b > 0);
        Self {
            max_wait: Duration::from_millis(20),
            service_queue: 256,
            global_queue: 2048,
            device_budget_bytes,
        }
    }
}

/// One prepared service: the device-resident model plus its batcher.
struct ServiceEntry {
    service: Arc<ModelService>,
    handle: BatcherHandle,
    batcher: Mutex<Batcher>,
    /// Residency ledger shared with the owning router, so teardown can
    /// return this instance's byte reservation no matter which path —
    /// release, re-registration, budget eviction, shutdown, or the Drop
    /// safety net — got there first.
    ledger: Arc<Mutex<Residency>>,
    torn: AtomicBool,
}

impl ServiceEntry {
    /// Drain the batcher (graceful: flushes in-flight batches, fails —
    /// never drops — queued requests), evict this instance's
    /// generation-tagged device buffers + panel-cache entries, and return
    /// its residency reservation. Idempotent: exactly one caller wins the
    /// `torn` flag, so racing teardown paths (explicit release vs budget
    /// eviction vs shutdown vs Drop) never double-drain.
    fn teardown(&self) {
        if self.torn.swap(true, Ordering::SeqCst) {
            return;
        }
        lock_sane(&self.batcher, "batcher").stop();
        self.service.release();
        Residency::remove(&self.ledger, self.service.weight_prefix());
    }
}

impl Drop for ServiceEntry {
    /// Safety net for entries orphaned by a racing release/re-registration
    /// (their slot was removed while preparation was still in flight, so
    /// explicit teardown never saw them). Idempotent with the explicit
    /// teardown path; eviction on a stopped engine is a no-op.
    fn drop(&mut self) {
        self.teardown();
    }
}

/// One resident tenant in the device-budget ledger.
struct Resident {
    key: ServiceKey,
    bytes: u64,
    /// Logical LRU clock value of the last touch (reservation or routed
    /// request).
    last_used: u64,
}

/// The device-residency ledger: who holds how many engine-resident weight
/// bytes, in LRU order. Bytes are **reserved here before they are
/// uploaded** (evict-before-upload) and returned on teardown, so
/// `bytes` never exceeds the configured budget even mid-preparation.
#[derive(Default)]
struct Residency {
    /// Logical LRU clock (bumped on every reservation/touch).
    tick: u64,
    /// Reserved bytes across all resident prefixes.
    bytes: u64,
    /// Generation-tagged weight prefix → tenant.
    resident: HashMap<String, Resident>,
    /// Keys evicted by the budget and not yet re-prepared — re-preparation
    /// accounting pops from here.
    evicted: HashSet<ServiceKey>,
}

impl Residency {
    /// Return a prefix's reservation (idempotent; unknown prefixes are a
    /// no-op).
    fn remove(ledger: &Mutex<Residency>, prefix: &str) {
        let mut led = lock_sane(ledger, "ledger");
        if let Some(r) = led.resident.remove(prefix) {
            led.bytes = led.bytes.saturating_sub(r.bytes);
        }
    }
}

/// Per-model rollout state: the policy plus how many canary-assigned
/// requests have completed since the canary started (the guard's
/// minimum-sample gate).
struct RolloutState {
    policy: RolloutPolicy,
    canary_seen: u64,
}

/// A lazily-prepared registry slot. The map lock is held only to fetch or
/// insert the slot; the (slow) preparation runs under the slot's
/// `OnceLock`, so preparing one service never blocks traffic to others,
/// and two threads racing on the same cold key prepare it exactly once.
type Slot = Arc<OnceLock<Result<Arc<ServiceEntry>, String>>>;

pub struct Router {
    eng: EngineHandle,
    engine_thread: Mutex<Option<EngineThread>>,
    cfg: RouterConfig,
    models: Mutex<HashMap<String, Arc<ParamSet>>>,
    /// Content-addressed plan registry: digest → plan. Plans are pure
    /// content (no device state), so they survive model re-registration;
    /// their *services* are torn down like any other.
    plans: Mutex<HashMap<String, Arc<QuantPlan>>>,
    services: Mutex<HashMap<ServiceKey, Slot>>,
    global_queued: Arc<AtomicUsize>,
    /// Per-model rollout policies ([`Router::set_rollout`]).
    rollouts: Mutex<HashMap<String, RolloutState>>,
    /// Device-residency ledger (shared with every [`ServiceEntry`] so
    /// teardown returns reservations on any path).
    ledger: Arc<Mutex<Residency>>,
    evictions: AtomicU64,
    repreparations: AtomicU64,
    /// Set under the `services` lock by shutdown, checked under the same
    /// lock by registration/preparation — the two sides can never miss
    /// each other (the shutdown/prepare race fix).
    shutting_down: AtomicBool,
    artifacts_dir: String,
    /// Background artifact compiler ([`Router::enable_compile_queue`]).
    compiler: Mutex<Option<Arc<CompileQueue>>>,
    /// Finished-compile flag shared with the queue's worker: the request
    /// hot path checks one relaxed load, and only drains outcomes (locks,
    /// manifest refresh, hot-swap) when a build actually completed.
    compile_pending: Arc<AtomicUsize>,
    /// Manifest re-read after a background compile; `None` until the
    /// first refresh. Preparations resolve against this when present, so
    /// post-boot artifacts become routable without restarting the engine.
    fresh_manifest: Mutex<Option<Arc<Manifest>>>,
}

impl Router {
    /// Spawn the engine thread over `artifacts_dir` with default policy.
    pub fn new(artifacts_dir: &str) -> Result<Router, String> {
        Self::with_config(artifacts_dir, RouterConfig::default())
    }

    pub fn with_config(artifacts_dir: &str, cfg: RouterConfig) -> Result<Router, String> {
        let (eng, thread) = EngineHandle::spawn(artifacts_dir)?;
        Ok(Router {
            eng,
            engine_thread: Mutex::new(Some(thread)),
            cfg,
            models: Mutex::new(HashMap::new()),
            plans: Mutex::new(HashMap::new()),
            services: Mutex::new(HashMap::new()),
            global_queued: Arc::new(AtomicUsize::new(0)),
            rollouts: Mutex::new(HashMap::new()),
            ledger: Arc::new(Mutex::new(Residency::default())),
            evictions: AtomicU64::new(0),
            repreparations: AtomicU64::new(0),
            shutting_down: AtomicBool::new(false),
            artifacts_dir: artifacts_dir.to_string(),
            compiler: Mutex::new(None),
            compile_pending: Arc::new(AtomicUsize::new(0)),
            fresh_manifest: Mutex::new(None),
        })
    }

    /// The shared engine handle (training and raw artifact execution go
    /// straight to the engine; only scoring is routed).
    pub fn engine(&self) -> &EngineHandle {
        &self.eng
    }

    pub fn manifest(&self) -> &Manifest {
        self.eng.manifest()
    }

    /// Register (or replace) the parameters served for `model`. Replacing
    /// releases every service already prepared for the model — their
    /// batchers drain first, then their device weights are evicted — so
    /// later requests lazily re-prepare against the new checkpoint.
    /// Requests racing a re-registration may still complete against the
    /// old weights. Returns the shared params for callers that keep using
    /// them host-side.
    pub fn register_model(&self, model: &str, params: ParamSet) -> Result<Arc<ParamSet>, String> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(format!("router is shutting down; rejecting registration of {model:?}"));
        }
        let meta = self.eng.manifest().config(model)?;
        params.validate(meta)?;
        let params = Arc::new(params);
        lock_sane(&self.models, "models").insert(model.to_string(), Arc::clone(&params));
        let stale: Vec<Slot> = {
            let mut services = lock_sane(&self.services, "services");
            let keys: Vec<ServiceKey> =
                services.keys().filter(|k| k.model == model).cloned().collect();
            keys.iter().filter_map(|k| services.remove(k)).collect()
        };
        for slot in stale {
            Self::teardown_slot(&slot);
        }
        Ok(params)
    }

    /// Models currently registered (sorted).
    pub fn registered_models(&self) -> Vec<String> {
        let mut v: Vec<String> = lock_sane(&self.models, "models").keys().cloned().collect();
        v.sort();
        v
    }

    /// Register a per-tensor [`QuantPlan`] and return the [`ServiceKey`]
    /// that serves it. Content-addressed: identical plans map to one key
    /// (idempotent re-registration), distinct plans of the same model get
    /// distinct keys and serve side by side behind the one engine. The
    /// service itself is prepared lazily on first request, like any other.
    ///
    /// Degenerate content — an empty plan, a zero-param tensor, B < 2, a
    /// dq-0 group — is rejected **here**, before the plan ever enters the
    /// registry ([`QuantPlan::validate_content`]); an empty plan used to
    /// register cleanly and only fail (or worse, serve nothing) at
    /// prepare time.
    pub fn register_plan(&self, plan: QuantPlan) -> Result<ServiceKey, String> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err("router is shutting down; rejecting plan registration".into());
        }
        plan.validate_content()?;
        let key = ServiceKey::planned(&plan);
        let plan = Arc::new(plan);
        lock_sane(&self.plans, "plans").insert(plan.digest().to_string(), Arc::clone(&plan));
        // An uncompiled heterogeneous shape starts its background build
        // now (if the compile queue is enabled), so the fused artifact is
        // often ready before — or shortly after — the first request lands
        // on the fallback.
        self.maybe_enqueue_compile(&key, &plan);
        Ok(key)
    }

    /// Digests of currently registered plans (sorted).
    pub fn registered_plans(&self) -> Vec<String> {
        let mut v: Vec<String> = lock_sane(&self.plans, "plans").keys().cloned().collect();
        v.sort();
        v
    }

    // ------------------------------------------------------------------
    // Weighted rollout
    // ------------------------------------------------------------------

    /// Install (or replace) the rollout policy for `model`. Every plan
    /// the policy references must already be registered — a policy that
    /// routes traffic to a plan the router cannot prepare is rejected
    /// here, not discovered per-request. The transition is logged and
    /// counted (`action="canary"` when the policy starts with a canary,
    /// `"set"` otherwise).
    pub fn set_rollout(&self, model: &str, policy: RolloutPolicy) -> Result<(), String> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err("router is shutting down; rejecting rollout update".into());
        }
        if !lock_sane(&self.models, "models").contains_key(model) {
            return Err(format!(
                "model {model:?} not registered with the router (registered: {:?})",
                self.registered_models()
            ));
        }
        {
            let plans = lock_sane(&self.plans, "plans");
            for p in policy.referenced_plans() {
                if let PlanRef::Digest(d) = p {
                    if !plans.contains_key(d) {
                        return Err(format!(
                            "rollout for {model:?} references unregistered plan {d:?} \
                             (see register_plan)"
                        ));
                    }
                }
            }
        }
        let action =
            if policy.canary().is_some() { RolloutAction::Canary } else { RolloutAction::Set };
        lock_sane(&self.rollouts, "rollouts")
            .insert(model.to_string(), RolloutState { policy, canary_seen: 0 });
        self.note_transition(model, action, None);
        Ok(())
    }

    /// The current rollout policy for `model`, if one is installed.
    pub fn rollout_of(&self, model: &str) -> Option<RolloutPolicy> {
        lock_sane(&self.rollouts, "rollouts").get(model).map(|s| s.policy.clone())
    }

    /// Deterministic weighted assignment: which service key the policy
    /// routes `span` to. Errors when no policy is installed for `model`.
    pub fn rollout_assign(&self, model: &str, span: u64) -> Result<ServiceKey, String> {
        self.assign_for(model, span).map(|(key, _)| key)
    }

    fn assign_for(&self, model: &str, span: u64) -> Result<(ServiceKey, bool), String> {
        let rollouts = lock_sane(&self.rollouts, "rollouts");
        let state = rollouts.get(model).ok_or_else(|| {
            format!("no rollout policy installed for model {model:?} (see set_rollout)")
        })?;
        let plan = state.policy.assign(span);
        let is_canary = state.policy.canary().map(|c| &c.plan == plan).unwrap_or(false);
        Ok((ServiceKey { model: model.to_string(), plan: plan.clone() }, is_canary))
    }

    /// Score one sequence through `model`'s rollout policy: assign a
    /// service by span hash, route through its batcher, and — when the
    /// request was canary-assigned — feed the canary health check.
    /// Returns the assigned key alongside the response so callers can
    /// attribute results to arms.
    pub fn score_rollout(
        &self,
        model: &str,
        ids: Vec<i32>,
        targets: Vec<i32>,
    ) -> Result<(ServiceKey, ScoreResponse), String> {
        let span = crate::obs::trace::next_span_id();
        let (key, is_canary) = self.assign_for(model, span)?;
        let res = self.score(ScoreRequest { key: key.clone(), span, ids, targets });
        if is_canary {
            self.note_canary(model);
        }
        res.map(|r| (key, r))
    }

    /// Operator promote: the canary becomes the sole arm. Future
    /// assignments re-point; in-flight requests finish where they are.
    pub fn promote(&self, model: &str) -> Result<(), String> {
        self.transition(model, RolloutAction::Promote)
    }

    /// Operator rollback: the canary is dropped, baseline unchanged.
    pub fn rollback(&self, model: &str) -> Result<(), String> {
        self.transition(model, RolloutAction::Rollback)
    }

    fn transition(&self, model: &str, action: RolloutAction) -> Result<(), String> {
        {
            let mut rollouts = lock_sane(&self.rollouts, "rollouts");
            let state = rollouts.get_mut(model).ok_or_else(|| {
                format!("no rollout policy installed for model {model:?} (see set_rollout)")
            })?;
            state.policy = match action {
                RolloutAction::Promote => state.policy.promoted()?,
                RolloutAction::Rollback | RolloutAction::AutoRollback => {
                    state.policy.rolled_back()?
                }
                RolloutAction::Set | RolloutAction::Canary => {
                    unreachable!("installs go through set_rollout")
                }
            };
            state.canary_seen = 0;
        }
        self.note_transition(model, action, None);
        Ok(())
    }

    fn note_transition(&self, model: &str, action: RolloutAction, why: Option<&str>) {
        crate::obs::registry::counter(&format!(
            "afq_rollout_transitions_total{{action={:?}}}",
            action.label()
        ))
        .inc(1);
        match why {
            Some(why) => {
                crate::log_warn!("router: rollout {} for {model}: {why}", action.label())
            }
            None => crate::log_info!("router: rollout {} for {model}", action.label()),
        }
    }

    /// A canary-assigned request completed: bump the sample counter and
    /// judge the canary once the guard's minimum sample is in.
    fn note_canary(&self, model: &str) {
        let due = {
            let mut rollouts = lock_sane(&self.rollouts, "rollouts");
            match rollouts.get_mut(model) {
                Some(state) if state.policy.canary().is_some() => {
                    state.canary_seen += 1;
                    state.canary_seen >= state.policy.canary().expect("checked").guard.min_requests
                }
                _ => false,
            }
        };
        if due {
            let _ = self.evaluate_canary(model);
        }
    }

    /// Judge `model`'s canary against its baseline arms using the live
    /// per-service latency/error snapshots: **auto-rollback** (logged,
    /// counted with `action="auto-rollback"`) when the canary's p99
    /// exceeds `max_p99_ratio` × the weighted baseline p99, or its error
    /// rate exceeds the baseline rate by more than
    /// `max_error_rate_delta`. Returns the action taken, if any. Public
    /// so operators (CLI/examples) can force an immediate judgement; the
    /// router also calls it itself once the guard's `min_requests`
    /// canary-assigned requests have completed.
    pub fn evaluate_canary(&self, model: &str) -> Result<Option<RolloutAction>, String> {
        let (policy, guard) = {
            let rollouts = lock_sane(&self.rollouts, "rollouts");
            let state = rollouts.get(model).ok_or_else(|| {
                format!("no rollout policy installed for model {model:?} (see set_rollout)")
            })?;
            match state.policy.canary() {
                Some(c) => (state.policy.clone(), c.guard),
                None => return Ok(None),
            }
        };
        let canary = policy.canary().expect("checked above");
        let canary_key =
            ServiceKey { model: model.to_string(), plan: canary.plan.clone() };
        let Some((c_p99, c_err, c_n)) = self.service_health(&canary_key) else {
            return Ok(None); // canary cold: nothing to judge yet
        };
        if c_n < guard.min_requests {
            return Ok(None);
        }
        // Weighted baseline over the prepared stable arms.
        let mut base_p99 = 0.0f64;
        let mut base_err = 0.0f64;
        let mut base_w = 0.0f64;
        for (plan, w) in policy.arms() {
            let key = ServiceKey { model: model.to_string(), plan: plan.clone() };
            if let Some((p99, err, n)) = self.service_health(&key) {
                if n > 0 {
                    base_p99 += w * p99;
                    base_err += w * err;
                    base_w += w;
                }
            }
        }
        if base_w <= 0.0 {
            return Ok(None); // no baseline evidence: don't judge blind
        }
        base_p99 /= base_w;
        base_err /= base_w;
        let p99_breach = base_p99 > 0.0 && c_p99 > guard.max_p99_ratio * base_p99;
        let err_breach = c_err > base_err + guard.max_error_rate_delta;
        if !(p99_breach || err_breach) {
            return Ok(None);
        }
        let why = format!(
            "canary {} breached its guard: p99 {c_p99:.0}µs vs baseline {base_p99:.0}µs \
             (max ratio {}), error rate {c_err:.4} vs baseline {base_err:.4} \
             (max delta {})",
            canary.plan.label(),
            guard.max_p99_ratio,
            guard.max_error_rate_delta
        );
        {
            let mut rollouts = lock_sane(&self.rollouts, "rollouts");
            if let Some(state) = rollouts.get_mut(model) {
                match state.policy.rolled_back() {
                    Ok(p) => {
                        state.policy = p;
                        state.canary_seen = 0;
                    }
                    // Someone promoted/rolled back between our snapshot
                    // and now: nothing left to do.
                    Err(_) => return Ok(None),
                }
            } else {
                return Ok(None);
            }
        }
        self.note_transition(model, RolloutAction::AutoRollback, Some(&why));
        Ok(Some(RolloutAction::AutoRollback))
    }

    /// (p99 µs, error rate, completed requests) for a prepared service —
    /// `None` when the service is cold or mid-preparation. p99 comes from
    /// the end-to-end stage histogram when the batcher path has traffic,
    /// falling back to the raw batch-latency histogram (the score_batch
    /// fast path bypasses the batcher).
    fn service_health(&self, key: &ServiceKey) -> Option<(f64, f64, u64)> {
        let entry = self.peek_entry(key)?;
        let m = &entry.service.metrics;
        let c = m.counters.snapshot();
        let completed = c.requests + c.errors;
        let p99 = if m.e2e.count() > 0 {
            m.e2e.quantile(0.99).as_micros() as f64
        } else {
            entry.service.latency.quantile(0.99).as_micros() as f64
        };
        let err_rate =
            if completed > 0 { c.errors as f64 / completed as f64 } else { 0.0 };
        Some((p99, err_rate, completed))
    }

    /// A prepared entry, without preparing cold ones (rollout health
    /// checks and hot-swap must never trigger preparation themselves).
    fn peek_entry(&self, key: &ServiceKey) -> Option<Arc<ServiceEntry>> {
        let slot = lock_sane(&self.services, "services").get(key).cloned()?;
        match slot.get() {
            Some(Ok(entry)) => Some(Arc::clone(entry)),
            _ => None,
        }
    }

    // ------------------------------------------------------------------
    // Scoring
    // ------------------------------------------------------------------

    /// Score one sequence through the keyed service's dynamic batcher.
    /// Lazily prepares the service on first use; fails fast under
    /// backpressure (global or per-service queue quota).
    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, String> {
        let entry = self.entry(&req.key)?;
        entry.handle.score_traced(req.span, req.ids, req.targets)
    }

    /// Full-batch fast path: score one pre-assembled [batch, seq] batch
    /// directly on the keyed service (no dynamic batching; still serialized
    /// through the shared engine thread). The eval/exp flows use this.
    pub fn score_batch(
        &self,
        key: &ServiceKey,
        ids: Vec<i32>,
        targets: Vec<i32>,
    ) -> Result<(Vec<f32>, Vec<i32>), String> {
        self.entry(key)?.service.score(ids, targets)
    }

    /// Batched fast path: score several pre-assembled [batch, seq]
    /// batches on the keyed service through one submission pass — the
    /// weight-argument tail is marshalled once and the engine sees the
    /// executions back-to-back (see [`ModelService::score_many`]). The
    /// batched-vs-per-request cost shows up as adjacent rows in
    /// `benches/serving.rs`.
    pub fn score_batches(
        &self,
        key: &ServiceKey,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<Vec<(Vec<f32>, Vec<i32>)>, String> {
        self.entry(key)?.service.score_many(batches)
    }

    /// Mean NLL/token of the keyed service over pre-assembled eval batches.
    pub fn mean_nll(&self, key: &ServiceKey, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<f64, String> {
        self.entry(key)?.service.mean_nll(batches)
    }

    /// Eagerly prepare a service (optional warmup; `score` does it lazily).
    pub fn prepare(&self, key: &ServiceKey) -> Result<(), String> {
        self.entry(key).map(|_| ())
    }

    /// Batch/seq shape of the keyed service's model (prepares it if cold).
    pub fn shape(&self, key: &ServiceKey) -> Result<(usize, usize), String> {
        let e = self.entry(key)?;
        Ok((e.service.batch(), e.service.seq()))
    }

    /// Drain and evict one service. Returns true if it had been prepared.
    pub fn release(&self, key: &ServiceKey) -> bool {
        let slot = lock_sane(&self.services, "services").remove(key);
        match slot {
            Some(slot) => {
                let had = matches!(slot.get(), Some(Ok(_)));
                Self::teardown_slot(&slot);
                had
            }
            None => false,
        }
    }

    /// Number of currently prepared (device-resident) services.
    pub fn service_count(&self) -> usize {
        lock_sane(&self.services, "services")
            .values()
            .filter(|s| matches!(s.get(), Some(Ok(_))))
            .count()
    }

    /// Requests queued across all services right now.
    pub fn queued(&self) -> usize {
        self.global_queued.load(Ordering::Relaxed)
    }

    /// Point-in-time report over every prepared service plus engine
    /// residency stats.
    pub fn snapshot(&self) -> RouterSnapshot {
        let entries: Vec<(ServiceKey, Arc<ServiceEntry>)> = {
            let services = lock_sane(&self.services, "services");
            services
                .iter()
                .filter_map(|(k, s)| {
                    s.get().and_then(|r| r.as_ref().ok()).map(|e| (k.clone(), Arc::clone(e)))
                })
                .collect()
        };
        let mut stats: Vec<ServiceStat> = entries
            .iter()
            .map(|(key, e)| {
                let m = &e.service.metrics;
                let c = m.counters.snapshot();
                let lat = &e.service.latency;
                let cs = crate::quant::panelcache::owner_stats(e.service.weight_prefix())
                    .unwrap_or_default();
                ServiceStat {
                    key: key.to_string(),
                    artifact: e.service.artifact().to_string(),
                    serving_path: e.service.path(),
                    device_bytes: e.service.device_bytes(),
                    requests: c.requests,
                    batches: c.batches,
                    tokens: c.tokens,
                    errors: c.errors,
                    aborted: c.aborted,
                    padded_slots: c.padded_slots,
                    batch_efficiency: m.counters.batch_efficiency(),
                    queued: e.handle.queued(),
                    p50_us: lat.quantile(0.50).as_micros() as u64,
                    p99_us: lat.quantile(0.99).as_micros() as u64,
                    mean_us: lat.mean().as_micros() as u64,
                    queue: StageStat::of(&m.queue),
                    batch_wait: StageStat::of(&m.batch_wait),
                    engine: StageStat::of(&m.engine),
                    e2e: StageStat::of(&m.e2e),
                    cache_bytes: cs.bytes,
                    cache_hits: cs.hits,
                    cache_misses: cs.misses,
                    cache_hit_rate: cs.hit_rate(),
                }
            })
            .collect();
        stats.sort_by(|a, b| a.key.cmp(&b.key));
        let estats = self.eng.stats();
        let mut rollouts: Vec<RolloutStat> = lock_sane(&self.rollouts, "rollouts")
            .iter()
            .map(|(model, state)| RolloutStat {
                model: model.clone(),
                arms: state
                    .policy
                    .arms()
                    .iter()
                    .map(|(p, w)| (p.label(), *w))
                    .collect(),
                canary: state.policy.canary().map(|c| c.plan.label()),
                canary_share: state.policy.canary().map(|c| c.share).unwrap_or(0.0),
                canary_requests: state.canary_seen,
            })
            .collect();
        rollouts.sort_by(|a, b| a.model.cmp(&b.model));
        RouterSnapshot {
            services: stats,
            queued: self.queued(),
            device_buffers: estats.cached_buffers,
            executables: estats.executables,
            device_bytes: estats.resident_bytes,
            device_budget: self.cfg.device_budget_bytes.unwrap_or(0),
            evictions: self.evictions.load(Ordering::Relaxed),
            repreparations: self.repreparations.load(Ordering::Relaxed),
            panelcache_bytes: crate::quant::panelcache::bytes_in_use(),
            models: self.registered_models(),
            rollouts,
        }
    }

    /// Graceful shutdown: drain every service's batcher through the engine
    /// (flushing in-flight batches), then stop the engine thread. Dropping
    /// the router does the same.
    pub fn shutdown(self) {
        self.shutdown_inner();
    }

    fn entry(&self, key: &ServiceKey) -> Result<Arc<ServiceEntry>, String> {
        // Piggyback on request traffic: if a background compile finished,
        // hot-swap before routing (one relaxed load when nothing did).
        if self.compile_pending.load(Ordering::Relaxed) > 0 {
            self.poll_compiled();
        }
        let slot: Slot = {
            let mut map = lock_sane(&self.services, "services");
            // Checked under the same lock shutdown holds for its drain:
            // either this insert lands before the drain snapshot (and is
            // torn down with it) or it is refused here — never a service
            // stranded past shutdown.
            if self.shutting_down.load(Ordering::SeqCst) {
                return Err(format!("router is shutting down; rejecting request for {key}"));
            }
            #[cfg(test)]
            test_hooks::maybe_panic_holding_services_lock();
            Arc::clone(map.entry(key.clone()).or_insert_with(|| Arc::new(OnceLock::new())))
        };
        let res = slot.get_or_init(|| self.prepare_entry(key));
        match res {
            Ok(entry) => {
                // A prepare can complete concurrently with shutdown's drain
                // (the slow path runs outside the services lock). Re-check:
                // if shutdown ran meanwhile, this entry either was in the
                // drain snapshot (torn down there; `torn` makes our extra
                // teardown a no-op) or raced past it — tear it down here so
                // nothing outlives shutdown.
                if self.shutting_down.load(Ordering::SeqCst) {
                    entry.teardown();
                    return Err(format!("router is shutting down; rejecting request for {key}"));
                }
                self.touch(entry.service.weight_prefix());
                Ok(Arc::clone(entry))
            }
            Err(e) => {
                // Don't cache failures: drop the slot (if it is still ours)
                // so a later request can retry — e.g. after the model gets
                // registered.
                let mut map = lock_sane(&self.services, "services");
                if let Some(cur) = map.get(key) {
                    if Arc::ptr_eq(cur, &slot) {
                        map.remove(key);
                    }
                }
                Err(e.clone())
            }
        }
    }

    fn prepare_entry(&self, key: &ServiceKey) -> Result<Arc<ServiceEntry>, String> {
        #[cfg(test)]
        test_hooks::maybe_delay_prepare();
        // NB: take the params clone in its own statement so the `models`
        // guard is dropped before the error path calls
        // `registered_models()` (which locks `models` again).
        let params = lock_sane(&self.models, "models").get(&key.model).cloned();
        let params = params.ok_or_else(|| {
            format!(
                "model {:?} not registered with the router (registered: {:?})",
                key.model,
                self.registered_models()
            )
        })?;
        let serve_plan = match &key.plan {
            PlanRef::Uniform(spec) => ServePlan::Uniform(spec.clone()),
            PlanRef::Digest(d) => {
                let plan = lock_sane(&self.plans, "plans").get(d).cloned();
                ServePlan::Planned(plan.ok_or_else(|| {
                    format!("plan {d:?} not registered with the router (see register_plan)")
                })?)
            }
        };
        crate::log_info!("router: preparing service {key}");
        // Resolve against the freshest manifest we have (post-compile
        // refreshes included), reserve device bytes against the residency
        // budget *before* anything is uploaded (evicting LRU idle tenants
        // as needed), then prepare under the reserved generation prefix.
        let manifest = self.current_manifest();
        let prefix = ModelService::generation_prefix(&serve_plan, &key.model);
        let reserve = |need: u64| self.reserve_bytes(key, &prefix, need);
        let service = match ModelService::prepare_at(
            &self.eng,
            &manifest,
            &key.model,
            &params,
            serve_plan,
            prefix.clone(),
            Some(&reserve),
        ) {
            Ok(s) => Arc::new(s),
            Err(e) => {
                // The reservation (if it was ever taken) must not outlive
                // the failed preparation.
                Residency::remove(&self.ledger, &prefix);
                return Err(e);
            }
        };
        // Account the lazy re-preparation of a budget-evicted tenant.
        if lock_sane(&self.ledger, "ledger").evicted.remove(key) {
            self.repreparations.fetch_add(1, Ordering::Relaxed);
            crate::obs::registry::counter("afq_router_repreparations_total").inc(1);
            crate::log_info!("router: re-prepared budget-evicted service {key}");
        }
        // A planned service that landed on the fp fallback wants its fused
        // artifact: make sure a build is queued (idempotent by shape).
        if service.path() == "plan-reconstructed-fp" {
            if let ServePlan::Planned(p) = &service.plan {
                self.maybe_enqueue_compile(key, p);
            }
        }
        let cfg = BatcherConfig {
            max_wait: self.cfg.max_wait,
            max_queue: self.cfg.service_queue,
            global_queued: Arc::clone(&self.global_queued),
            max_global_queue: self.cfg.global_queue,
        };
        let (handle, batcher) =
            Batcher::spawn(Arc::clone(&service) as Arc<dyn ScoreBackend>, cfg);
        Ok(Arc::new(ServiceEntry {
            service,
            handle,
            batcher: Mutex::new(batcher),
            ledger: Arc::clone(&self.ledger),
            torn: AtomicBool::new(false),
        }))
    }

    // ------------------------------------------------------------------
    // Device-residency budget
    // ------------------------------------------------------------------

    /// Bump a resident prefix's LRU clock (routed traffic keeps a tenant
    /// warm; idle tenants age toward eviction).
    fn touch(&self, prefix: &str) {
        let mut led = lock_sane(&self.ledger, "ledger");
        led.tick += 1;
        let tick = led.tick;
        if let Some(r) = led.resident.get_mut(prefix) {
            r.last_used = tick;
        }
    }

    /// Reserve `need` bytes for `prefix` against the device budget,
    /// evicting least-recently-used other tenants until it fits
    /// (evict-before-upload: the ledger — and therefore the engine cache —
    /// never overshoots the budget). Always records the reservation, even
    /// without a budget, so the snapshot and LRU order stay meaningful.
    fn reserve_bytes(&self, key: &ServiceKey, prefix: &str, need: u64) -> Result<(), String> {
        let budget = self.cfg.device_budget_bytes;
        if let Some(b) = budget {
            if need > b {
                return Err(format!(
                    "service {key} needs {need}B of device weights but the budget is {b}B \
                     (AFQ_DEVICE_BUDGET_BYTES / RouterConfig::device_budget_bytes)"
                ));
            }
        }
        loop {
            {
                let mut led = lock_sane(&self.ledger, "ledger");
                if budget.map_or(true, |b| led.bytes + need <= b) {
                    led.tick += 1;
                    let tick = led.tick;
                    led.bytes += need;
                    led.resident.insert(
                        prefix.to_string(),
                        Resident { key: key.clone(), bytes: need, last_used: tick },
                    );
                    return Ok(());
                }
            }
            if !self.evict_one_for_budget(prefix) {
                let b = budget.expect("loop only spins when a budget is set");
                return Err(format!(
                    "device budget {b}B cannot fit {need}B for {key}: nothing evictable \
                     (all other resident services are busy or mid-preparation)"
                ));
            }
        }
    }

    /// Evict the least-recently-used other tenant: prefer idle services
    /// (empty queue), fall back to busy ones (their queued requests fail
    /// explicitly on drain — deliberate: an explicit error beats an
    /// unservable fleet). Returns whether anything was freed. Only fully
    /// prepared entries are victims — an in-flight preparation holds its
    /// reservation but has no initialized slot yet, so it cannot be
    /// evicted out from under itself.
    fn evict_one_for_budget(&self, skip_prefix: &str) -> bool {
        let mut candidates: Vec<(String, ServiceKey, u64)> = {
            let led = lock_sane(&self.ledger, "ledger");
            led.resident
                .iter()
                .filter(|(p, _)| p.as_str() != skip_prefix)
                .map(|(p, r)| (p.clone(), r.key.clone(), r.last_used))
                .collect()
        };
        candidates.sort_by_key(|(_, _, last_used)| *last_used);
        for require_idle in [true, false] {
            for (prefix, key, _) in &candidates {
                let slot = match lock_sane(&self.services, "services").get(key).cloned() {
                    Some(s) => s,
                    None => {
                        // Ledger row without a routed slot: a racing
                        // release/re-registration already claimed the entry;
                        // its teardown returns the bytes. Skip.
                        continue;
                    }
                };
                let Some(Ok(entry)) = slot.get() else {
                    continue; // mid-preparation: not a victim
                };
                if entry.service.weight_prefix() != prefix.as_str() {
                    continue; // slot was re-prepared under a newer generation
                }
                if require_idle && entry.handle.queued() > 0 {
                    continue;
                }
                // Claim the slot (only if it is still the routed one), then
                // tear down outside the services lock.
                let claimed = {
                    let mut map = lock_sane(&self.services, "services");
                    match map.get(key) {
                        Some(cur) if Arc::ptr_eq(cur, &slot) => {
                            map.remove(key);
                            true
                        }
                        _ => false,
                    }
                };
                if !claimed {
                    continue;
                }
                lock_sane(&self.ledger, "ledger").evicted.insert(key.clone());
                self.evictions.fetch_add(1, Ordering::Relaxed);
                crate::obs::registry::counter("afq_router_evictions_total").inc(1);
                crate::log_info!(
                    "router: budget-evicting LRU service {key} ({}B)",
                    entry.service.device_bytes()
                );
                entry.teardown();
                return true;
            }
        }
        false
    }

    // ------------------------------------------------------------------
    // Background compilation + hot-swap
    // ------------------------------------------------------------------

    /// Turn on background artifact compilation. `worker` defaults to
    /// [`crate::coordinator::compile::default_worker`] over this router's
    /// artifacts directory (shelling to `python/compile/aot.py`); tests and
    /// build farms inject their own. Idempotent-ish: enabling again
    /// replaces the queue (the old worker drains and joins).
    pub fn enable_compile_queue(&self, worker: Option<CompileWorker>) -> Result<(), String> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err("router is shutting down; rejecting compile queue".into());
        }
        let worker = worker
            .unwrap_or_else(|| crate::coordinator::compile::default_worker(&self.artifacts_dir));
        let q = CompileQueue::with_worker_and_flag(worker, Arc::clone(&self.compile_pending))?;
        *lock_sane(&self.compiler, "compiler") = Some(Arc::new(q));
        crate::log_info!("router: compile queue enabled over {:?}", self.artifacts_dir);
        Ok(())
    }

    /// The manifest preparations resolve against: the latest post-compile
    /// refresh when one happened, the boot manifest otherwise.
    fn current_manifest(&self) -> Arc<Manifest> {
        lock_sane(&self.fresh_manifest, "fresh_manifest")
            .clone()
            .unwrap_or_else(|| self.eng.manifest_arc())
    }

    /// Queue a background build for a heterogeneous plan whose fused
    /// artifact is missing. No-op when the queue is disabled, the plan is
    /// uniform (served fused already), or the artifact exists.
    fn maybe_enqueue_compile(&self, key: &ServiceKey, plan: &Arc<QuantPlan>) {
        if plan.uniform_spec().is_some() {
            return;
        }
        let Some(q) = lock_sane(&self.compiler, "compiler").clone() else {
            return;
        };
        if self.current_manifest().artifacts.contains_key(&plan.fused_artifact_name()) {
            return;
        }
        if q.submit(CompileJob {
            key: key.clone(),
            model: key.model.clone(),
            plan: Arc::clone(plan),
        }) {
            crate::log_info!(
                "router: queued background compile of {} for {key}",
                plan.fused_artifact_name()
            );
        }
    }

    /// Drain finished compiles and hot-swap their services onto the fused
    /// path. Returns how many services were swapped. Called from the
    /// request path (one relaxed load when idle) and callable directly by
    /// tests/operators.
    pub fn poll_compiled(&self) -> usize {
        if self.compile_pending.load(Ordering::Relaxed) == 0 {
            return 0;
        }
        let Some(q) = lock_sane(&self.compiler, "compiler").clone() else {
            return 0;
        };
        let outcomes = q.drain();
        let mut swapped = 0usize;
        let mut refreshed = false;
        for o in outcomes {
            if o.result.is_err() {
                continue; // already logged + counted by the queue worker
            }
            if !refreshed {
                // One manifest re-read covers every outcome in this drain.
                match self.eng.refresh_manifest() {
                    Ok(m) => {
                        *lock_sane(&self.fresh_manifest, "fresh_manifest") =
                            Some(Arc::new(m));
                        refreshed = true;
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "router: compile finished but manifest refresh failed: {e}"
                        );
                        return swapped;
                    }
                }
            }
            match self.hot_swap(&o.job.key) {
                Ok(true) => swapped += 1,
                Ok(false) => {}
                Err(e) => {
                    crate::log_warn!("router: hot-swap of {} failed: {e}", o.job.key)
                }
            }
        }
        swapped
    }

    /// Atomically replace a fallback-path service with a freshly prepared
    /// fused instance. The flip happens under the services lock (requests
    /// route to exactly one of old/new); the old instance then drains
    /// gracefully — its queued requests complete on the old weights, so
    /// nothing is dropped or double-counted. Returns whether a swap
    /// happened (cold, already-fused, or mid-preparation services are left
    /// alone).
    fn hot_swap(&self, key: &ServiceKey) -> Result<bool, String> {
        let Some(old_slot) = lock_sane(&self.services, "services").get(key).cloned() else {
            return Ok(false); // cold: its next prepare sees the new manifest
        };
        let Some(Ok(old_entry)) = old_slot.get() else {
            return Ok(false); // mid-preparation: it resolves the fresh manifest itself
        };
        if old_entry.service.path() != "plan-reconstructed-fp" {
            return Ok(false);
        }
        let fresh = self.prepare_entry(key)?;
        if fresh.service.path() != "plan-fused" {
            // Still no fused artifact (e.g. stub compiler wrote nothing for
            // this shape): keep the fallback.
            fresh.teardown();
            return Ok(false);
        }
        let new_slot: Slot = Arc::new(OnceLock::new());
        let _ = new_slot.set(Ok(Arc::clone(&fresh)));
        let installed = {
            let mut map = lock_sane(&self.services, "services");
            if self.shutting_down.load(Ordering::SeqCst) {
                false
            } else {
                match map.get(key) {
                    Some(cur) if Arc::ptr_eq(cur, &old_slot) => {
                        map.insert(key.clone(), new_slot);
                        true
                    }
                    _ => false, // released/re-registered/evicted meanwhile
                }
            }
        };
        if !installed {
            fresh.teardown();
            return Ok(false);
        }
        let old = Arc::clone(old_entry);
        old.teardown(); // graceful drain: queued requests finish on old weights
        crate::obs::registry::counter("afq_router_hot_swaps_total").inc(1);
        crate::log_info!(
            "router: hot-swapped {key} onto fused artifact {}",
            fresh.service.artifact()
        );
        Ok(true)
    }

    /// Stop a removed slot's batcher (graceful drain) and evict its
    /// weights. No-op for slots whose preparation failed or never ran.
    fn teardown_slot(slot: &Slot) {
        if let Some(Ok(entry)) = slot.get() {
            entry.teardown();
        }
    }

    fn shutdown_inner(&self) {
        // Stop the compile worker first: a build finishing mid-shutdown
        // must not hot-swap into the drain. Dropping the queue joins it.
        drop(lock_sane(&self.compiler, "compiler").take());
        // Set the flag and snapshot the drain under ONE services lock:
        // a racing prepare either landed before (drained here) or fails
        // its shutting-down check — the shutdown/prepare race fix.
        let slots: Vec<Slot> = {
            let mut map = lock_sane(&self.services, "services");
            self.shutting_down.store(true, Ordering::SeqCst);
            map.drain().map(|(_, s)| s).collect()
        };
        for slot in &slots {
            Self::teardown_slot(slot);
        }
        // Only after every batcher has drained may the engine thread stop.
        if let Some(mut th) = lock_sane(&self.engine_thread, "engine_thread").take() {
            th.stop(&self.eng);
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Quantile/mean digest of one request-lifecycle stage histogram, so the
/// snapshot says *where* latency lives (queue vs batch-wait vs engine),
/// not just how much there is end to end.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStat {
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: u64,
    /// Exact µs sum — stage sums telescope to the e2e sum (tracer
    /// invariant), so consumers can cross-check consistency.
    pub sum_us: u64,
}

impl StageStat {
    fn of(h: &crate::coordinator::metrics::LatencyHistogram) -> StageStat {
        StageStat {
            count: h.count(),
            p50_us: h.quantile(0.50).as_micros() as u64,
            p99_us: h.quantile(0.99).as_micros() as u64,
            mean_us: h.mean().as_micros() as u64,
            sum_us: h.sum_us(),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", Json::Num(self.count as f64))
            .set("p50_us", Json::Num(self.p50_us as f64))
            .set("p99_us", Json::Num(self.p99_us as f64))
            .set("mean_us", Json::Num(self.mean_us as f64))
            .set("sum_us", Json::Num(self.sum_us as f64));
        o
    }
}

/// Per-service row of a [`RouterSnapshot`].
#[derive(Clone, Debug)]
pub struct ServiceStat {
    /// Display form of the service key (`model/family@B` or `model/fp`).
    pub key: String,
    /// The executable this service scores on (`score_q<B>_…`,
    /// `score_plan_<shape_digest>_…`, `score_fp_…`) — shows which serving
    /// path a planned service landed on (fused vs reconstructed-fp).
    pub artifact: String,
    /// [`crate::coordinator::metrics::serving_path`] classification of the
    /// artifact (`plan-fused`, `plan-reconstructed-fp`, `fp`,
    /// `uniform-fused`).
    pub serving_path: &'static str,
    /// Engine-resident weight bytes this service instance holds (what the
    /// device budget charges it for).
    pub device_bytes: u64,
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub errors: u64,
    /// Requests admitted but failed by a hard shutdown (never executed).
    pub aborted: u64,
    pub padded_slots: u64,
    pub batch_efficiency: f64,
    pub queued: usize,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: u64,
    /// Stage histograms: admitted → picked out of the queue.
    pub queue: StageStat,
    /// Picked → batch dispatched to the engine.
    pub batch_wait: StageStat,
    /// Dispatched → scored (shared per batch).
    pub engine: StageStat,
    /// Admitted → reply construction (the whole request lifecycle).
    pub e2e: StageStat,
    /// Decoded-panel cache bytes currently held for this service's weights
    /// (0 when the cache is disabled or nothing is resident).
    pub cache_bytes: u64,
    /// Panel-cache hits attributed to this service's weight prefix.
    pub cache_hits: u64,
    /// Panel-cache misses attributed to this service's weight prefix.
    pub cache_misses: u64,
    /// hits / (hits + misses), 0.0 when no lookups happened.
    pub cache_hit_rate: f64,
}

impl ServiceStat {
    pub fn to_json(&self) -> Json {
        let mut stages = Json::obj();
        stages
            .set("queue", self.queue.to_json())
            .set("batch_wait", self.batch_wait.to_json())
            .set("engine", self.engine.to_json())
            .set("e2e", self.e2e.to_json());
        let mut o = Json::obj();
        o.set("key", Json::Str(self.key.clone()))
            .set("artifact", Json::Str(self.artifact.clone()))
            .set("serving_path", Json::Str(self.serving_path.to_string()))
            .set("device_bytes", Json::Num(self.device_bytes as f64))
            .set("requests", Json::Num(self.requests as f64))
            .set("batches", Json::Num(self.batches as f64))
            .set("tokens", Json::Num(self.tokens as f64))
            .set("errors", Json::Num(self.errors as f64))
            .set("aborted", Json::Num(self.aborted as f64))
            .set("padded_slots", Json::Num(self.padded_slots as f64))
            .set("batch_efficiency", Json::Num(self.batch_efficiency))
            .set("queued", Json::Num(self.queued as f64))
            .set("p50_us", Json::Num(self.p50_us as f64))
            .set("p99_us", Json::Num(self.p99_us as f64))
            .set("mean_us", Json::Num(self.mean_us as f64))
            .set("cache_bytes", Json::Num(self.cache_bytes as f64))
            .set("cache_hits", Json::Num(self.cache_hits as f64))
            .set("cache_misses", Json::Num(self.cache_misses as f64))
            .set("cache_hit_rate", Json::Num(self.cache_hit_rate))
            .set("stages", stages);
        o
    }
}

impl std::fmt::Display for ServiceStat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<24} [{}] req {:>6}  batches {:>5}  err {:>3}  abrt {:>3}  eff {:>5.1}%  queued {:>4}  p50≈{:>7}µs  p99≈{:>7}µs  mean µs q/b/e {}/{}/{}",
            self.key,
            self.serving_path,
            self.requests,
            self.batches,
            self.errors,
            self.aborted,
            self.batch_efficiency * 100.0,
            self.queued,
            self.p50_us,
            self.p99_us,
            self.queue.mean_us,
            self.batch_wait.mean_us,
            self.engine.mean_us,
        )
    }
}

/// One model's rollout policy as the snapshot reports it.
#[derive(Clone, Debug)]
pub struct RolloutStat {
    pub model: String,
    /// Stable arms: (plan label, normalized weight).
    pub arms: Vec<(String, f64)>,
    /// Canary plan label, if one is running.
    pub canary: Option<String>,
    /// Canary traffic share (0.0 when no canary).
    pub canary_share: f64,
    /// Canary-assigned requests completed since the canary started.
    pub canary_requests: u64,
}

impl RolloutStat {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.clone()))
            .set(
                "arms",
                Json::Arr(
                    self.arms
                        .iter()
                        .map(|(label, w)| {
                            let mut a = Json::obj();
                            a.set("plan", Json::Str(label.clone()))
                                .set("weight", Json::Num(*w));
                            a
                        })
                        .collect(),
                ),
            )
            .set(
                "canary",
                match &self.canary {
                    Some(c) => Json::Str(c.clone()),
                    None => Json::Null,
                },
            )
            .set("canary_share", Json::Num(self.canary_share))
            .set("canary_requests", Json::Num(self.canary_requests as f64));
        o
    }
}

/// Point-in-time view of the whole router.
#[derive(Clone, Debug)]
pub struct RouterSnapshot {
    /// One row per prepared service, sorted by key.
    pub services: Vec<ServiceStat>,
    /// Requests queued across all services.
    pub queued: usize,
    /// Named device-resident buffers held by the engine.
    pub device_buffers: usize,
    /// Compiled executables held by the engine.
    pub executables: usize,
    /// Host-byte size of the engine's device-resident buffer cache.
    pub device_bytes: u64,
    /// Configured residency budget (0 = unlimited).
    pub device_budget: u64,
    /// Services evicted by the residency budget since boot.
    pub evictions: u64,
    /// Budget-evicted services lazily re-prepared since boot.
    pub repreparations: u64,
    /// Host decoded-panel cache bytes in use across all services (0 when
    /// `AFQ_PANEL_CACHE_BYTES` is unset — the cache is opt-in).
    pub panelcache_bytes: u64,
    /// Registered model names.
    pub models: Vec<String>,
    /// Installed rollout policies, sorted by model.
    pub rollouts: Vec<RolloutStat>,
}

impl RouterSnapshot {
    /// Row for one service key, if prepared.
    pub fn get(&self, key: &ServiceKey) -> Option<&ServiceStat> {
        let k = key.to_string();
        self.services.iter().find(|s| s.key == k)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("services", Json::Arr(self.services.iter().map(|s| s.to_json()).collect()))
            .set("queued", Json::Num(self.queued as f64))
            .set("device_buffers", Json::Num(self.device_buffers as f64))
            .set("executables", Json::Num(self.executables as f64))
            .set("device_bytes", Json::Num(self.device_bytes as f64))
            .set("device_budget", Json::Num(self.device_budget as f64))
            .set("evictions", Json::Num(self.evictions as f64))
            .set("repreparations", Json::Num(self.repreparations as f64))
            .set("panelcache_bytes", Json::Num(self.panelcache_bytes as f64))
            .set(
                "models",
                Json::from_strs(&self.models.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
            )
            .set("rollouts", Json::Arr(self.rollouts.iter().map(|r| r.to_json()).collect()));
        o
    }
}

impl std::fmt::Display for RouterSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "router: {} service(s), {} model(s), {} queued, {} device buffers, {} executables, {} panel-cache bytes",
            self.services.len(),
            self.models.len(),
            self.queued,
            self.device_buffers,
            self.executables,
            self.panelcache_bytes
        )?;
        writeln!(
            f,
            "  device: {} bytes resident / budget {}, {} eviction(s), {} re-preparation(s)",
            self.device_bytes,
            if self.device_budget == 0 {
                "unlimited".to_string()
            } else {
                format!("{} bytes", self.device_budget)
            },
            self.evictions,
            self.repreparations
        )?;
        for r in &self.rollouts {
            let arms: Vec<String> =
                r.arms.iter().map(|(p, w)| format!("{p}:{:.2}", w)).collect();
            write!(f, "  rollout {}: [{}]", r.model, arms.join(", "))?;
            match &r.canary {
                Some(c) => writeln!(
                    f,
                    " canary {c} @ {:.2} ({} req)",
                    r.canary_share, r.canary_requests
                )?,
                None => writeln!(f)?,
            }
        }
        for s in &self.services {
            writeln!(f, "  {s}")?;
        }
        Ok(())
    }
}

/// Failure-injection points for the poisoning and shutdown-race tests.
/// Compiled only under `cfg(test)`; production builds carry no hooks.
#[cfg(test)]
pub(crate) mod test_hooks {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    /// One-shot: the next `entry()` panics while holding the services
    /// lock, poisoning it.
    pub static PANIC_HOLDING_SERVICES: AtomicBool = AtomicBool::new(false);
    /// Every `prepare_entry()` sleeps this long before doing anything —
    /// widens the shutdown/prepare race window deterministically.
    pub static PREPARE_DELAY_MS: AtomicU64 = AtomicU64::new(0);

    pub fn maybe_panic_holding_services_lock() {
        if PANIC_HOLDING_SERVICES.swap(false, Ordering::SeqCst) {
            panic!("test hook: panicking while holding the services lock");
        }
    }

    pub fn maybe_delay_prepare() {
        let ms = PREPARE_DELAY_MS.load(Ordering::SeqCst);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{corpus, BatchSampler, ParamSet};

    fn router() -> Option<Router> {
        if !crate::util::artifacts_available("artifacts") {
            return None;
        }
        Some(Router::new("artifacts").expect("router"))
    }

    fn registered_router(seed: u64) -> Option<(Router, crate::runtime::ModelMeta)> {
        let r = router()?;
        let meta = r.manifest().config("tiny").unwrap().clone();
        r.register_model("tiny", ParamSet::init(&meta, seed)).unwrap();
        Some((r, meta))
    }

    fn toy_plan(model: &str, labels: &[(&str, &str)]) -> crate::plan::QuantPlan {
        use crate::plan::Assignment;
        crate::plan::QuantPlan::new(
            model,
            labels
                .iter()
                .map(|(tensor, label)| Assignment {
                    tensor: tensor.to_string(),
                    n_params: 16,
                    spec: QuantSpec::parse_label(label).unwrap(),
                    dq: None,
                    bits_per_param: 0.0,
                    predicted_l1: 0.0,
                })
                .collect(),
        )
    }

    #[test]
    fn service_key_display_and_hash() {
        let a = ServiceKey::quant("tiny", "nf4", 64);
        let b = ServiceKey::quant("tiny", "nf4", 4096);
        let c = ServiceKey::fp("tiny");
        assert_eq!(a.to_string(), "tiny/nf4@64");
        assert_eq!(c.to_string(), "tiny/fp");
        assert_eq!(a.config_label(), "nf4@64");
        let p1 = toy_plan("tiny", &[("w", "nf4@64")]);
        let p2 = toy_plan("tiny", &[("w", "af4@64")]);
        let kp1 = ServiceKey::planned(&p1);
        let kp2 = ServiceKey::planned(&p2);
        assert_eq!(kp1.to_string(), format!("tiny/plan:{}", p1.digest()));
        assert_ne!(kp1, kp2, "distinct plans are distinct tenants");
        assert_eq!(kp1, ServiceKey::planned(&toy_plan("tiny", &[("w", "nf4@64")])));
        let mut m = std::collections::HashMap::new();
        m.insert(a.clone(), 1);
        m.insert(b, 2);
        m.insert(c, 3);
        m.insert(kp1, 4);
        m.insert(kp2, 5);
        assert_eq!(m.len(), 5);
        assert_eq!(m[&a], 1);
    }

    #[test]
    fn plan_registry_is_content_addressed() {
        let Some(r) = router() else { return };
        let k1 = r.register_plan(toy_plan("tiny", &[("w", "nf4@64")])).unwrap();
        let k1b = r.register_plan(toy_plan("tiny", &[("w", "nf4@64")])).unwrap();
        let k2 = r.register_plan(toy_plan("tiny", &[("w", "af4@64")])).unwrap();
        assert_eq!(k1, k1b, "identical plans land on one key");
        assert_ne!(k1, k2);
        assert_eq!(r.registered_plans().len(), 2);
        // Scoring an unregistered plan digest fails with a clear error and
        // stays retryable (no cached failure).
        let meta = r.manifest().config("tiny").unwrap().clone();
        r.register_model("tiny", ParamSet::init(&meta, 9)).unwrap();
        let ghost = ServiceKey {
            model: "tiny".into(),
            plan: PlanRef::Digest("deadbeefdeadbeef".into()),
        };
        let e = r.prepare(&ghost).unwrap_err();
        assert!(e.contains("not registered"), "{e}");
        assert_eq!(r.service_count(), 0);
    }

    /// Regression (satellite): an empty plan — or one with a zero-param
    /// tensor — used to pass validation and register cleanly; now the
    /// router rejects it at the registry door with a clear error.
    #[test]
    fn register_plan_rejects_empty_and_zero_param_plans() {
        let Some(r) = router() else { return };
        let empty = crate::plan::QuantPlan::new("tiny", vec![]);
        let e = r.register_plan(empty).unwrap_err();
        assert!(e.contains("no tensor assignments"), "{e}");
        let zero = crate::plan::QuantPlan::new(
            "tiny",
            vec![crate::plan::Assignment {
                tensor: "w".into(),
                n_params: 0,
                spec: QuantSpec::parse_label("nf4@64").unwrap(),
                dq: None,
                bits_per_param: 0.0,
                predicted_l1: 0.0,
            }],
        );
        let e = r.register_plan(zero).unwrap_err();
        assert!(e.contains("n_params == 0"), "{e}");
        assert!(r.registered_plans().is_empty(), "rejected plans must not enter the registry");
    }

    #[test]
    fn unregistered_model_errors_and_is_retryable() {
        let Some(r) = router() else { return };
        let key = ServiceKey::quant("tiny", "nf4", 64);
        let e = r.prepare(&key).unwrap_err();
        assert!(e.contains("not registered"), "{e}");
        assert_eq!(r.service_count(), 0);
        // Registering afterwards heals the path (no cached failure).
        let meta = r.manifest().config("tiny").unwrap().clone();
        r.register_model("tiny", ParamSet::init(&meta, 1)).unwrap();
        r.prepare(&key).expect("prepare after registration");
        assert_eq!(r.service_count(), 1);
    }

    /// The acceptance scenario: ≥3 (code × B) configs device-resident
    /// behind one engine thread, hit by concurrent clients, each request's
    /// result exactly matching that service's direct full-batch scoring —
    /// and the per-service counters tallying the submitted request counts.
    #[test]
    fn concurrent_multi_service_routing_is_correct_and_counted() {
        // Hold the trace test lock: this test asserts exact stage-histogram
        // counts, so no parallel test may flip the global tracing flag.
        let _trace_guard = crate::obs::trace::lock_for_tests();
        let Some((r, meta)) = registered_router(21) else { return };
        let keys = [
            ServiceKey::quant("tiny", "nf4", 64),
            ServiceKey::quant("tiny", "af4", 64),
            ServiceKey::quant("tiny", "af4", 4096),
        ];
        let data = corpus::english(60_000, 5);
        let seq = meta.seq_len;
        let clients_per_service = 2usize;
        let reqs_per_client = 2usize;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for (ki, key) in keys.iter().enumerate() {
                for c in 0..clients_per_service {
                    let r = &r;
                    let data = &data;
                    let key = key.clone();
                    joins.push(s.spawn(move || {
                        let mut out = Vec::new();
                        for q in 0..reqs_per_client {
                            let off = (ki * 31 + c * 7 + q) * 400;
                            let ids: Vec<i32> =
                                data[off..off + seq].iter().map(|&b| b as i32).collect();
                            let tgt: Vec<i32> =
                                data[off + 1..off + seq + 1].iter().map(|&b| b as i32).collect();
                            let resp = r
                                .score(ScoreRequest::new(&key, ids.clone(), tgt.clone()))
                                .expect("routed score");
                            assert_eq!(resp.nll.len(), seq);
                            out.push((key.clone(), ids, tgt, resp));
                        }
                        out
                    }));
                }
            }
            for j in joins {
                for (key, ids, tgt, resp) in j.join().unwrap() {
                    // Reference: broadcast the row into a full direct batch
                    // on the same service; the routed answer must match.
                    let mut bids = Vec::new();
                    let mut btgt = Vec::new();
                    for _ in 0..meta.batch {
                        bids.extend_from_slice(&ids);
                        btgt.extend_from_slice(&tgt);
                    }
                    let (nll, _) = r.score_batch(&key, bids, btgt).unwrap();
                    for (a, b) in resp.nll.iter().zip(&nll[..seq]) {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{key}: routed vs direct: {a} vs {b} (cross-service interleaving?)"
                        );
                    }
                }
            }
        });
        // All three services live behind the one engine thread.
        assert_eq!(r.service_count(), 3);
        let snap = r.snapshot();
        assert_eq!(snap.services.len(), 3);
        let expected = (clients_per_service * reqs_per_client) as u64;
        for key in &keys {
            let stat = snap.get(key).expect("stat row");
            assert_eq!(
                stat.requests, expected,
                "{key}: counters must tally exactly the submitted requests"
            );
            assert!(stat.batches >= 1);
            assert!(stat.errors == 0);
            assert!(stat.p99_us >= stat.p50_us);
            assert_eq!(stat.serving_path, "uniform-fused");
            // The snapshot says WHERE latency lives: each stage histogram
            // saw every routed request exactly once (score_batch bypasses
            // the batcher, so only the routed `expected` count here) …
            for st in [&stat.queue, &stat.batch_wait, &stat.engine, &stat.e2e] {
                assert_eq!(st.count, expected, "{key}: stage counts");
            }
            // … and the stage sums are consistent with the end-to-end sum
            // (they partition it on one monotonic clock; slack covers the
            // per-observation µs clamp/truncation of 4 histograms).
            let parts = stat.queue.sum_us + stat.batch_wait.sum_us + stat.engine.sum_us;
            let slack = expected * 4 * 2;
            assert!(
                parts <= stat.e2e.sum_us + slack && stat.e2e.sum_us <= parts + slack,
                "{key}: stage sums {parts}µs vs e2e {}µs (slack {slack}µs)",
                stat.e2e.sum_us
            );
        }
        assert_eq!(snap.queued, 0);
        assert!(snap.device_buffers > 0);
        // nf4@64 and af4@64 share the score_q64 executable; af4@4096 adds
        // score_q4096 (+ the direct-score reference adds nothing new).
        assert!(snap.executables >= 2);
        r.shutdown();
    }

    /// The planner acceptance scenario: two DISTINCT QuantPlans of the
    /// same model (built by the real allocator at different budgets),
    /// device-resident side by side behind one engine thread, hit by
    /// concurrent clients — every routed result matching that service's
    /// direct scoring, and per-service counters tallying exactly the
    /// submitted request counts.
    #[test]
    fn two_plans_of_one_model_serve_concurrently() {
        use crate::plan::{plan_for_params, Candidate, ErrorModel, PlannerOpts};
        let Some((r, meta)) = registered_router(71) else { return };
        let params = ParamSet::init(&meta, 71); // same seed = same registered weights
        let grid: Vec<Candidate> = [64usize, 1024, 4096]
            .iter()
            .flat_map(|&b| {
                ["nf4", "af4"].iter().map(move |f| {
                    Candidate::new(QuantSpec { family: f.to_string(), block_size: b })
                })
            })
            .collect();
        let mk_plan = |budget: f64| {
            plan_for_params(
                &meta,
                &params,
                &PlannerOpts {
                    budget_bits: budget,
                    grid: grid.clone(),
                    error_model: ErrorModel::Predicted,
                },
            )
            .expect("plan builds")
        };
        let plan_lo = mk_plan(4.05); // B=64 (4.5 bits) infeasible here
        let plan_hi = mk_plan(4.60);
        assert_ne!(plan_lo.digest(), plan_hi.digest(), "budgets must yield distinct plans");
        assert!(plan_lo.avg_bits_per_param() <= 4.05 + 1e-6);
        let keys = [r.register_plan(plan_lo).unwrap(), r.register_plan(plan_hi).unwrap()];
        assert_eq!(r.registered_plans().len(), 2);

        let data = corpus::english(60_000, 7);
        let seq = meta.seq_len;
        let clients_per_plan = 2usize;
        let reqs_per_client = 2usize;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for (ki, key) in keys.iter().enumerate() {
                for c in 0..clients_per_plan {
                    let r = &r;
                    let data = &data;
                    let key = key.clone();
                    joins.push(s.spawn(move || {
                        let mut out = Vec::new();
                        for q in 0..reqs_per_client {
                            let off = (ki * 37 + c * 11 + q) * 300;
                            let ids: Vec<i32> =
                                data[off..off + seq].iter().map(|&b| b as i32).collect();
                            let tgt: Vec<i32> =
                                data[off + 1..off + seq + 1].iter().map(|&b| b as i32).collect();
                            let resp = r
                                .score(ScoreRequest::new(&key, ids.clone(), tgt.clone()))
                                .expect("routed score");
                            assert_eq!(resp.nll.len(), seq);
                            out.push((key.clone(), ids, tgt, resp));
                        }
                        out
                    }));
                }
            }
            for j in joins {
                for (key, ids, tgt, resp) in j.join().unwrap() {
                    let mut bids = Vec::new();
                    let mut btgt = Vec::new();
                    for _ in 0..meta.batch {
                        bids.extend_from_slice(&ids);
                        btgt.extend_from_slice(&tgt);
                    }
                    let (nll, _) = r.score_batch(&key, bids, btgt).unwrap();
                    for (a, b) in resp.nll.iter().zip(&nll[..seq]) {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{key}: routed vs direct: {a} vs {b} (cross-plan interleaving?)"
                        );
                    }
                }
            }
        });
        assert_eq!(r.service_count(), 2, "both plans live behind the one engine");
        let snap = r.snapshot();
        let expected = (clients_per_plan * reqs_per_client) as u64;
        for key in &keys {
            let stat = snap.get(key).expect("stat row for planned service");
            assert!(stat.key.contains("plan:"), "planned keys are digest-labelled: {}", stat.key);
            assert_eq!(
                stat.requests, expected,
                "{key}: counters must tally exactly the submitted requests"
            );
            assert_eq!(stat.errors, 0);
        }
        assert_eq!(snap.queued, 0);
        r.shutdown();
    }

    /// A/B extension (satellite): ONE model served simultaneously as (a) a
    /// uniform spec, (b) the degenerate one-entry plan of that same spec,
    /// and (c) a genuinely heterogeneous plan — three tenants behind one
    /// engine. (a) and (b) must produce **identical** outputs (same
    /// executable, same quantized bytes, distinct device buffers), the
    /// heterogeneous plan must land on its fused `score_plan` executable
    /// whenever the manifest carries one (fp fallback otherwise), and
    /// per-service counters must tally exactly the submitted requests.
    #[test]
    fn uniform_degenerate_and_heterogeneous_serve_concurrently() {
        use crate::plan::{canonical_mixed_plan, Assignment};
        let Some((r, meta)) = registered_router(61) else { return };
        let spec = QuantSpec { family: "nf4".into(), block_size: 64 };
        let uniform_key = ServiceKey::new("tiny", spec.clone());
        let degenerate = crate::plan::QuantPlan::new(
            "tiny",
            meta.matrix_order
                .iter()
                .map(|(name, shape)| Assignment {
                    tensor: name.clone(),
                    n_params: shape.iter().product(),
                    spec: spec.clone(),
                    dq: None,
                    bits_per_param: 0.0,
                    predicted_l1: 0.0,
                })
                .collect(),
        );
        assert!(degenerate.uniform_spec().is_some());
        let degenerate_key = r.register_plan(degenerate).unwrap();
        let het = canonical_mixed_plan(&meta, &["nf4", "af4"]);
        assert!(het.uniform_spec().is_none());
        let het_fused_artifact = het.fused_artifact_name();
        let het_key = r.register_plan(het).unwrap();
        let keys = [uniform_key.clone(), degenerate_key.clone(), het_key.clone()];

        let data = corpus::english(60_000, 9);
        let seq = meta.seq_len;
        let clients_per_service = 2usize;
        let reqs_per_client = 2usize;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for (ki, key) in keys.iter().enumerate() {
                for c in 0..clients_per_service {
                    let r = &r;
                    let data = &data;
                    let key = key.clone();
                    joins.push(s.spawn(move || {
                        for q in 0..reqs_per_client {
                            let off = (ki * 29 + c * 13 + q) * 350;
                            let ids: Vec<i32> =
                                data[off..off + seq].iter().map(|&b| b as i32).collect();
                            let tgt: Vec<i32> =
                                data[off + 1..off + seq + 1].iter().map(|&b| b as i32).collect();
                            let resp =
                                r.score(ScoreRequest::new(&key, ids, tgt)).expect("routed score");
                            assert_eq!(resp.nll.len(), seq);
                        }
                    }));
                }
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        assert_eq!(r.service_count(), 3, "all three tenants behind one engine");

        // (a) vs (b): identical full-batch outputs — the degenerate plan
        // routes through the same fused executable over the same
        // quantized bytes, so there is no tolerance to allow.
        let ids: Vec<i32> = data[..seq].iter().map(|&b| b as i32).collect();
        let tgt: Vec<i32> = data[1..seq + 1].iter().map(|&b| b as i32).collect();
        let mut bids = Vec::new();
        let mut btgt = Vec::new();
        for _ in 0..meta.batch {
            bids.extend_from_slice(&ids);
            btgt.extend_from_slice(&tgt);
        }
        let (nll_u, cor_u) = r.score_batch(&uniform_key, bids.clone(), btgt.clone()).unwrap();
        let (nll_d, cor_d) = r.score_batch(&degenerate_key, bids.clone(), btgt.clone()).unwrap();
        assert_eq!(nll_u, nll_d, "degenerate plan must be bitwise the uniform service");
        assert_eq!(cor_u, cor_d);
        // (c) serves and is numerically sane (random-init logits ≈ ln V).
        let (nll_h, _) = r.score_batch(&het_key, bids, btgt).unwrap();
        let mean_h = nll_h.iter().map(|&x| x as f64).sum::<f64>() / nll_h.len() as f64;
        assert!((mean_h - (256f64).ln()).abs() < 0.5, "het plan nll {mean_h}");

        let snap = r.snapshot();
        let expected = (clients_per_service * reqs_per_client) as u64;
        for key in &keys {
            let stat = snap.get(key).expect("stat row");
            assert_eq!(
                stat.requests, expected,
                "{key}: counters must tally exactly the submitted requests"
            );
            assert_eq!(stat.errors, 0, "{key}");
        }
        // Observable serving paths: the uniform pair shares score_q64, the
        // heterogeneous plan runs fused when its artifact is baked.
        assert_eq!(snap.get(&uniform_key).unwrap().artifact, "score_q64_tiny");
        assert_eq!(snap.get(&degenerate_key).unwrap().artifact, "score_q64_tiny");
        let het_artifact = &snap.get(&het_key).unwrap().artifact;
        if r.manifest().artifacts.contains_key(&het_fused_artifact) {
            assert_eq!(het_artifact, &het_fused_artifact, "must serve in the nibble domain");
        } else {
            assert_eq!(het_artifact, "score_fp_tiny", "fallback without a baked artifact");
        }
        r.shutdown();
    }

    #[test]
    fn lazy_prepare_release_and_reregistration() {
        let Some((r, meta)) = registered_router(31) else { return };
        assert_eq!(r.service_count(), 0, "registration must not prepare eagerly");
        let key = ServiceKey::quant("tiny", "nf4", 256);
        let ids: Vec<i32> = vec![1; meta.batch * meta.seq_len];
        let (nll_a, _) = r.score_batch(&key, ids.clone(), ids.clone()).unwrap();
        assert_eq!(r.service_count(), 1, "first request prepares lazily");
        r.score_batch(&key, ids.clone(), ids.clone()).unwrap();
        assert_eq!(r.service_count(), 1, "second request reuses the service");
        assert!(r.release(&key));
        assert_eq!(r.service_count(), 0);
        assert!(!r.release(&key), "double release is a no-op");
        // Re-register with different params: the same key must now serve
        // the new weights (fresh lazy prepare), not a stale cache.
        r.register_model("tiny", ParamSet::init(&meta, 77)).unwrap();
        let (nll_b, _) = r.score_batch(&key, ids.clone(), ids).unwrap();
        assert_eq!(r.service_count(), 1);
        let da: f64 = nll_a.iter().map(|&x| x as f64).sum();
        let db: f64 = nll_b.iter().map(|&x| x as f64).sum();
        assert!((da - db).abs() > 1e-9, "different checkpoints must score differently");
    }

    #[test]
    fn reregistration_releases_prepared_services() {
        let Some((r, meta)) = registered_router(41) else { return };
        let k1 = ServiceKey::quant("tiny", "nf4", 64);
        let k2 = ServiceKey::fp("tiny");
        r.prepare(&k1).unwrap();
        r.prepare(&k2).unwrap();
        assert_eq!(r.service_count(), 2);
        r.register_model("tiny", ParamSet::init(&meta, 42)).unwrap();
        assert_eq!(r.service_count(), 0, "stale services must be torn down");
    }

    #[test]
    fn mean_nll_via_router_matches_expectation() {
        let Some((r, meta)) = registered_router(11) else { return };
        let data = corpus::english(40_000, 1);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let nll_fp = r.mean_nll(&ServiceKey::fp("tiny"), &batches).unwrap();
        let nll_q = r.mean_nll(&ServiceKey::quant("tiny", "nf4", 64), &batches).unwrap();
        assert!((nll_fp - (256f64).ln()).abs() < 0.5, "fp nll {nll_fp}");
        assert!((nll_q - nll_fp).abs() < 0.1, "q {nll_q} vs fp {nll_fp}");
    }

    #[test]
    fn snapshot_json_shape() {
        let Some((r, meta)) = registered_router(51) else { return };
        let key = ServiceKey::quant("tiny", "nf4", 64);
        let ids: Vec<i32> = vec![2; meta.batch * meta.seq_len];
        r.score_batch(&key, ids.clone(), ids).unwrap();
        let j = r.snapshot().to_json();
        let services = j.get("services").unwrap().as_arr().unwrap();
        assert_eq!(services.len(), 1);
        assert_eq!(services[0].get("key").unwrap().as_str().unwrap(), "tiny/nf4@64");
        assert_eq!(
            services[0].get("serving_path").unwrap().as_str().unwrap(),
            "uniform-fused"
        );
        // The stage blocks are present even when the batcher never ran
        // (score_batch bypasses it): zero counts, well-formed shape.
        for stage in ["queue", "batch_wait", "engine", "e2e"] {
            let count = services[0].at(&["stages", stage, "count"]).unwrap().as_f64().unwrap();
            assert!(count >= 0.0, "{stage}");
        }
        assert!(services[0].get("aborted").unwrap().as_f64().is_some());
        // Panel-cache fields are present (zeros when the cache is disabled,
        // which is the default in tests that don't opt in).
        for field in ["cache_bytes", "cache_hits", "cache_misses", "cache_hit_rate"] {
            assert!(services[0].get(field).unwrap().as_f64().unwrap() >= 0.0, "{field}");
        }
        assert!(j.get("panelcache_bytes").unwrap().as_f64().unwrap() >= 0.0);
        assert!(j.get("device_buffers").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("models").unwrap().as_arr().unwrap()[0].as_str().unwrap(),
            "tiny"
        );
        // Fleet-operations fields: residency accounting and rollout list.
        assert!(
            services[0].get("device_bytes").unwrap().as_f64().unwrap() > 0.0,
            "a prepared service holds device weight bytes"
        );
        assert!(j.get("device_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("device_budget").unwrap().as_f64().unwrap(), 0.0, "unlimited");
        assert!(j.get("evictions").unwrap().as_f64().unwrap() >= 0.0);
        assert!(j.get("repreparations").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("rollouts").unwrap().as_arr().unwrap().len(), 0);
        // Install a rollout and check the stat row round-trips.
        r.set_rollout(
            "tiny",
            crate::coordinator::rollout::RolloutPolicy::single(7, key.plan.clone()),
        )
        .unwrap();
        let j = r.snapshot().to_json();
        let rollouts = j.get("rollouts").unwrap().as_arr().unwrap();
        assert_eq!(rollouts.len(), 1);
        assert_eq!(rollouts[0].get("model").unwrap().as_str().unwrap(), "tiny");
        assert_eq!(rollouts[0].get("arms").unwrap().as_arr().unwrap().len(), 1);
        assert!(
            rollouts[0].get("canary").unwrap().as_str().is_none(),
            "no canary installed → null"
        );
    }

    /// Satellite 1 (mechanism): a panicking holder poisons a mutex;
    /// `lock_sane` must recover the guard, count the recovery, and hand
    /// back consistent data. Artifact-free.
    #[test]
    fn lock_sane_recovers_from_poison() {
        let m = Mutex::new(7i32);
        let before =
            crate::obs::registry::counter("afq_router_lock_poisoned_total").get();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison the mutex");
        }));
        assert!(r.is_err());
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*lock_sane(&m, "test"), 7, "recovered guard sees the data");
        let after =
            crate::obs::registry::counter("afq_router_lock_poisoned_total").get();
        assert!(after >= before + 1, "recovery must be counted");
        // And the lock keeps working afterwards.
        *lock_sane(&m, "test") = 8;
        assert_eq!(*lock_sane(&m, "test"), 8);
    }

    /// Satellite 1 (end to end): a panic while holding the router's
    /// services lock — injected via a test hook where a buggy prepare
    /// would sit — must not take the router down. Before the fix, every
    /// subsequent request panicked on the poisoned lock; now the router
    /// recovers, counts it, and keeps serving.
    #[test]
    fn poisoned_router_still_serves() {
        let Some((r, meta)) = registered_router(81) else { return };
        let before =
            crate::obs::registry::counter("afq_router_lock_poisoned_total").get();
        test_hooks::PANIC_HOLDING_SERVICES.store(true, Ordering::SeqCst);
        let key = ServiceKey::quant("tiny", "nf4", 64);
        let panicked = std::thread::scope(|s| {
            s.spawn(|| r.prepare(&key)).join().is_err()
        });
        assert!(panicked, "the hooked request must panic while holding the lock");
        test_hooks::PANIC_HOLDING_SERVICES.store(false, Ordering::SeqCst);
        // The fleet survives: a different service prepares and scores.
        let other = ServiceKey::quant("tiny", "af4", 256);
        let ids: Vec<i32> = vec![3; meta.batch * meta.seq_len];
        r.score_batch(&other, ids.clone(), ids)
            .expect("router serves after a poisoned lock");
        let after =
            crate::obs::registry::counter("afq_router_lock_poisoned_total").get();
        assert!(after >= before + 1, "the recovery must be observable");
        r.shutdown();
    }

    /// Satellite 2: shutdown racing in-flight preparations. Each prepare
    /// either completes (and is drained by shutdown) or fails with an
    /// explicit shutting-down/engine-gone error — never a panic, never a
    /// stranded service, and late arrivals are refused.
    #[test]
    fn shutdown_vs_prepare_interleaving() {
        let Some((r, _meta)) = registered_router(91) else { return };
        test_hooks::PREPARE_DELAY_MS.store(120, Ordering::SeqCst);
        let keys = [
            ServiceKey::quant("tiny", "nf4", 64),
            ServiceKey::quant("tiny", "nf4", 256),
            ServiceKey::quant("tiny", "nf4", 1024),
        ];
        std::thread::scope(|s| {
            let joins: Vec<_> = keys
                .iter()
                .map(|key| {
                    let r = &r;
                    s.spawn(move || r.prepare(key))
                })
                .collect();
            // Let the prepares enter their delay window, then shut down
            // from under them.
            std::thread::sleep(Duration::from_millis(30));
            r.shutdown_inner();
            for j in joins {
                match j.join().expect("prepare must not panic") {
                    Ok(()) => {} // landed before the drain: torn down with it
                    Err(e) => assert!(
                        e.contains("shutting down") || e.contains("engine thread gone"),
                        "unexpected race error: {e}"
                    ),
                }
            }
        });
        test_hooks::PREPARE_DELAY_MS.store(0, Ordering::SeqCst);
        assert_eq!(r.service_count(), 0, "nothing may outlive shutdown");
        let e = r.prepare(&keys[0]).unwrap_err();
        assert!(
            e.contains("shutting down") || e.contains("engine thread gone"),
            "late arrivals must be refused explicitly: {e}"
        );
    }
}
