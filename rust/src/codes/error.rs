//! Expected reconstruction error of a code under a distribution, computed
//! from the CDF alone (Stieltjes integration by parts), so it is exact for
//! mixed distributions like `F_X(·; B)` whose atoms sit at bin-interior
//! points ±1.
//!
//! For a bin [lo, hi] with code value a ∈ [lo, hi]:
//!
//! ```text
//! ∫ |x − a| dF = −(a − lo)·F(lo) + ∫_lo^a F dx            (left part)
//!              + (hi − a)·F(hi) − ∫_a^hi F dx              (right part)
//! ```
//!
//! and similarly for squared error. Quadrature is adaptive Simpson on the
//! CDF, which is smooth inside bins (atoms only at the outermost bin edges,
//! where the by-parts boundary terms place their mass exactly).

use crate::codes::code::Code;
use crate::dist::Dist1D;
use crate::numerics::quad::adaptive_simpson;

const QUAD_TOL: f64 = 1e-10;

/// `F(x⁻)`: the CDF's left limit — subtracts any atom sitting exactly at x.
/// The Stieltjes by-parts boundary term at a bin's LOWER edge must use the
/// left limit; using F(lo) directly silently cancels an atom at lo (caught
/// by the Monte-Carlo cross-check tests).
fn cdf_left_limit(dist: &dyn Dist1D, x: f64) -> f64 {
    let mut v = dist.cdf(x);
    for (loc, mass) in dist.atoms() {
        if (loc - x).abs() < 1e-12 {
            v -= mass;
        }
    }
    v.max(0.0)
}

/// Expected L1 reconstruction error `E[min_j |Y − a_j|]`.
pub fn expected_l1(code: &Code, dist: &dyn Dist1D) -> f64 {
    let (slo, shi) = dist.support();
    let k = code.k();
    let mut total = 0.0;
    for j in 0..k {
        let lo = if j == 0 { slo } else { code.boundaries()[j - 1] };
        let hi = if j == k - 1 { shi } else { code.boundaries()[j] };
        let a = code.values[j].clamp(lo, hi);
        let f = |x: f64| dist.cdf(x);
        // left: ∫_[lo,a] (a−x) dF = −(a−lo)·F(lo⁻) + ∫_lo^a F dx
        if a > lo {
            total += -(a - lo) * cdf_left_limit(dist, lo) + adaptive_simpson(&f, lo, a, QUAD_TOL);
        }
        // right: ∫_(a,hi] (x−a) dF = (hi−a)·F(hi) − ∫_a^hi F dx
        if hi > a {
            total += (hi - a) * dist.cdf(hi) - adaptive_simpson(&f, a, hi, QUAD_TOL);
        }
    }
    total
}

/// Expected squared reconstruction error `E[min_j (Y − a_j)²]`.
pub fn expected_l2(code: &Code, dist: &dyn Dist1D) -> f64 {
    let (slo, shi) = dist.support();
    let k = code.k();
    let mut total = 0.0;
    for j in 0..k {
        let lo = if j == 0 { slo } else { code.boundaries()[j - 1] };
        let hi = if j == k - 1 { shi } else { code.boundaries()[j] };
        let a = code.values[j];
        // ∫_[lo,hi] (x−a)² dF = (hi−a)²F(hi) − (lo−a)²F(lo⁻) − 2∫ (x−a)F dx
        let boundary =
            (hi - a).powi(2) * dist.cdf(hi) - (lo - a).powi(2) * cdf_left_limit(dist, lo);
        let integral = adaptive_simpson(&|x: f64| (x - a) * dist.cdf(x), lo, hi, QUAD_TOL);
        total += boundary - 2.0 * integral;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{BlockScaledDist, Dist1D, ScaledNormal};
    use crate::util::rng::Rng;

    #[test]
    fn expected_l1_matches_monte_carlo() {
        let dist = BlockScaledDist::new(32);
        let code = crate::codes::nf4::nf4();
        let exact = expected_l1(&code, &dist);
        let mut rng = Rng::new(17);
        let xs = dist.sample(&mut rng, 4000);
        let emp = code.empirical_l1(&xs);
        assert!(
            (exact - emp).abs() / exact < 0.03,
            "exact {exact} vs MC {emp}"
        );
    }

    #[test]
    fn expected_l2_matches_monte_carlo() {
        let dist = BlockScaledDist::new(32);
        let code = crate::codes::nf4::nf4();
        let exact = expected_l2(&code, &dist);
        let mut rng = Rng::new(23);
        let xs = dist.sample(&mut rng, 4000);
        let emp = code.empirical_l2(&xs);
        assert!(
            (exact - emp).abs() / exact < 0.05,
            "exact {exact} vs MC {emp}"
        );
    }

    #[test]
    fn single_value_code_on_normal() {
        // E|Y - 0| for Y ~ N(0, σ²) is σ·sqrt(2/π); test with a degenerate
        // 2-value code {−ε, ε} ≈ {0}.
        let d = ScaledNormal { sigma: 0.5 };
        let code = crate::codes::code::Code::new("pair", vec![-1e-9, 1e-9]);
        let want = 0.5 * (2.0 / std::f64::consts::PI).sqrt();
        let got = expected_l1(&code, &d);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn finer_codes_have_lower_error() {
        let dist = BlockScaledDist::new(64);
        let coarse = crate::codes::code::Code::new(
            "c4",
            vec![-1.0, -0.33, 0.33, 1.0],
        );
        let fine = crate::codes::nf4::nf4();
        assert!(expected_l1(&fine, &dist) < expected_l1(&coarse, &dist));
        assert!(expected_l2(&fine, &dist) < expected_l2(&coarse, &dist));
    }

    #[test]
    fn endpoint_codes_match_monte_carlo() {
        // The atoms at ±1 must be accounted exactly by the by-parts
        // quadrature — cross-check both an endpoint-holding and an
        // endpoint-free code against Monte Carlo.
        let dist = BlockScaledDist::new(16); // big atoms: 1/32 each
        let with = crate::codes::code::Code::new("w", vec![-1.0, -0.4, 0.0, 0.4, 1.0]);
        let without = crate::codes::code::Code::new("wo", vec![-0.8, -0.4, 0.0, 0.4, 0.8]);
        let mut rng = Rng::new(29);
        let xs = dist.sample(&mut rng, 20_000);
        for code in [&with, &without] {
            let exact = expected_l1(code, &dist);
            let emp = code.empirical_l1(&xs);
            assert!(
                (exact - emp).abs() / exact < 0.03,
                "{}: exact {exact} vs MC {emp}",
                code.name
            );
        }
    }
}
