//! The [`Code`] type: an ordered set of 4-bit (or k-bit) code values in
//! [−1, 1], with nearest-value encoding, bin boundaries, usage histograms,
//! and empirical reconstruction-error metrics.

use crate::util::json::Json;

/// A quantization code: `k = values.len()` sorted values in [−1, 1].
/// NF4/AF4 have k = 16 (4 bits); the framework supports any k ≥ 2 so the
/// bit-width ablations can reuse the same machinery.
#[derive(Clone, Debug, PartialEq)]
pub struct Code {
    pub name: String,
    /// Sorted, deduplicated code values.
    pub values: Vec<f64>,
    /// Precomputed bin boundaries: midpoints between adjacent values.
    /// `boundaries[j]` separates bin j from bin j+1 (len = k − 1).
    boundaries: Vec<f64>,
}

impl Code {
    pub fn new(name: &str, mut values: Vec<f64>) -> Self {
        assert!(values.len() >= 2, "a code needs at least 2 values");
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in values.windows(2) {
            assert!(
                w[1] - w[0] > 1e-12,
                "code values must be strictly increasing: {w:?} in {name}"
            );
        }
        let boundaries = values.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
        Self { name: name.to_string(), values, boundaries }
    }

    pub fn k(&self) -> usize {
        self.values.len()
    }

    pub fn bits(&self) -> u32 {
        (self.k() as f64).log2().ceil() as u32
    }

    /// Bin boundaries (midpoints), length k−1.
    pub fn boundaries(&self) -> &[f64] {
        &self.boundaries
    }

    /// Encode a (pre-scaled) value in [−1, 1] to the nearest code index.
    /// Ties resolve to the lower index (bisection on midpoints), matching
    /// the Pallas kernel and pure-jnp reference.
    #[inline]
    pub fn encode(&self, x: f64) -> u8 {
        // binary search over boundaries: first boundary >= x gives the bin
        let mut lo = 0usize;
        let mut hi = self.boundaries.len(); // == k-1
        while lo < hi {
            let mid = (lo + hi) / 2;
            if x > self.boundaries[mid] {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as u8
    }

    #[inline]
    pub fn decode(&self, idx: u8) -> f64 {
        self.values[idx as usize]
    }

    /// f32 table (what gets shipped to kernels / the runtime).
    pub fn table_f32(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32).collect()
    }

    /// Usage histogram: fraction of `xs` assigned to each code value.
    pub fn usage(&self, xs: &[f64]) -> Vec<f64> {
        let mut counts = vec![0usize; self.k()];
        for &x in xs {
            counts[self.encode(x) as usize] += 1;
        }
        let n = xs.len().max(1) as f64;
        counts.into_iter().map(|c| c as f64 / n).collect()
    }

    /// Usage histogram over f32 samples.
    pub fn usage_f32(&self, xs: &[f32]) -> Vec<f64> {
        let mut counts = vec![0usize; self.k()];
        for &x in xs {
            counts[self.encode(x as f64) as usize] += 1;
        }
        let n = xs.len().max(1) as f64;
        counts.into_iter().map(|c| c as f64 / n).collect()
    }

    /// Empirical mean |x − decode(encode(x))| over samples.
    pub fn empirical_l1(&self, xs: &[f64]) -> f64 {
        let mut s = 0.0;
        for &x in xs {
            s += (x - self.decode(self.encode(x))).abs();
        }
        s / xs.len().max(1) as f64
    }

    /// Empirical mean squared reconstruction error.
    pub fn empirical_l2(&self, xs: &[f64]) -> f64 {
        let mut s = 0.0;
        for &x in xs {
            let e = x - self.decode(self.encode(x));
            s += e * e;
        }
        s / xs.len().max(1) as f64
    }

    /// Does the code contain a value within eps of `v`?
    pub fn contains(&self, v: f64, eps: f64) -> bool {
        self.values.iter().any(|&q| (q - v).abs() <= eps)
    }

    /// Includes the three "essential" values −1, 0, +1 (paper §5)?
    pub fn has_endpoints_and_zero(&self) -> bool {
        self.contains(-1.0, 1e-9) && self.contains(0.0, 1e-9) && self.contains(1.0, 1e-9)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()))
            .set("k", Json::Num(self.k() as f64))
            .set("values", Json::from_f64s(&self.values));
        o
    }

    pub fn from_json(j: &Json) -> Option<Code> {
        let name = j.get("name")?.as_str()?.to_string();
        let values = j
            .get("values")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Option<Vec<_>>>()?;
        Some(Code::new(&name, values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn toy() -> Code {
        Code::new("toy", vec![-1.0, -0.5, 0.0, 0.5, 1.0])
    }

    #[test]
    fn encode_picks_nearest() {
        let c = toy();
        assert_eq!(c.encode(-1.0), 0);
        assert_eq!(c.encode(-0.76), 0);
        assert_eq!(c.encode(-0.74), 1);
        assert_eq!(c.encode(0.01), 2);
        assert_eq!(c.encode(0.26), 3);
        assert_eq!(c.encode(0.99), 4);
        assert_eq!(c.encode(2.0), 4); // clamps beyond support
        assert_eq!(c.encode(-2.0), 0);
    }

    #[test]
    fn encode_tie_goes_low() {
        let c = toy();
        // exactly on boundary -0.75 between bins 0 and 1
        assert_eq!(c.encode(-0.75), 0);
        assert_eq!(c.encode(0.25), 2);
    }

    #[test]
    fn decode_roundtrip_on_code_values() {
        let c = toy();
        for (i, &v) in c.values.iter().enumerate() {
            assert_eq!(c.encode(v), i as u8);
            assert_eq!(c.decode(i as u8), v);
        }
    }

    #[test]
    fn values_sorted_on_construction() {
        let c = Code::new("x", vec![1.0, -1.0, 0.0]);
        assert_eq!(c.values, vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn duplicate_values_rejected() {
        Code::new("dup", vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn usage_sums_to_one() {
        let c = toy();
        let xs: Vec<f64> = (0..1000).map(|i| -1.0 + 2.0 * i as f64 / 999.0).collect();
        let u = c.usage(&xs);
        let total: f64 = u.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert_eq!(u.len(), 5);
    }

    #[test]
    fn l1_zero_on_exact_values() {
        let c = toy();
        assert_eq!(c.empirical_l1(&c.values.clone()), 0.0);
        assert_eq!(c.empirical_l2(&c.values.clone()), 0.0);
    }

    #[test]
    fn json_roundtrip() {
        let c = toy();
        let j = c.to_json().to_string_pretty();
        let back = Code::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn endpoints_check() {
        assert!(toy().has_endpoints_and_zero());
        let c = Code::new("no0", vec![-1.0, -0.3, 0.4, 1.0]);
        assert!(!c.has_endpoints_and_zero());
    }

    #[test]
    fn prop_encode_is_nearest_brute_force() {
        let c = toy();
        prop::check(512, |g| {
            let x = g.f64_in(-1.5, 1.5);
            let fast = c.encode(x) as usize;
            let brute = c
                .values
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    let da = (x - **a).abs();
                    let db = (x - **b).abs();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap()
                .0;
            let d_fast = (x - c.values[fast]).abs();
            let d_brute = (x - c.values[brute]).abs();
            if (d_fast - d_brute).abs() > 1e-12 {
                return Err(format!("encode({x}) gave {fast} (d={d_fast}), brute {brute} (d={d_brute})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_l1_bounded_by_half_max_gap() {
        // For x inside [-1,1], reconstruction error <= half the largest gap.
        let c = toy();
        let max_gap = c
            .values
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(0.0f64, f64::max);
        prop::check(512, |g| {
            let x = g.f64_in(-1.0, 1.0);
            let e = (x - c.decode(c.encode(x))).abs();
            if e > max_gap / 2.0 + 1e-12 {
                return Err(format!("error {e} exceeds half max gap"));
            }
            Ok(())
        });
    }
}
