//! Quantization codes: NF4 (§2), AF4 (§4.2/§5), balanced/uniform-usage
//! codes (§4.1, Appendix B), expected-error functionals, and the memoized
//! per-`(code, B)` predicted-error table ([`predict`]) that the
//! quantization planner ([`crate::plan`]) minimizes over.

pub mod af4;
pub mod balanced;
pub mod code;
pub mod error;
pub mod nf4;
pub mod predict;
pub mod registry;

pub use af4::{af4, kmedians_unpinned, l1_pinned_code};
pub use balanced::{balanced, balanced_with_endpoints, equal_mass_boundaries};
pub use code::Code;
pub use error::{expected_l1, expected_l2};
pub use nf4::{nf4, nf4_avg_quantiles, NF4_REFERENCE};
pub use predict::{predicted_errors, predicted_l1};
