//! AF4 — the 4-bit AbnormalFloat code (§4.2 and §5 of the paper).
//!
//! AF4-B minimizes the expected **L1** reconstruction error
//! `E[min_j |Y − a_j|]` over the block-scaled distribution `F_X(·; B)`,
//! subject to the pinned values a₁ = −1, a₈ = 0, a₁₆ = 1 (which the paper
//! finds essential for LM quality even though they hurt raw error).
//!
//! The stationarity condition (Eq. 4) says each code value is the median of
//! its bin; it yields the forward recursion (Eq. 5)
//!
//! ```text
//! ρ_j     = 2·F(a_j) − F((a_{j−1} + a_j)/2)
//! a_{j+1} = 2·F⁻¹(ρ_j) − a_j
//! ```
//!
//! so the whole code is determined by two consecutive values. We solve the
//! two halves by **shooting** (Eq. 6): search a₂ ∈ (−1, 0) so that the
//! recursion lands exactly on a₈ = 0, then a₉ ∈ (0, 1) so that it lands on
//! a₁₆ = 1. A pinned Lloyd iteration (median update with projections) is
//! provided as an independent cross-check, and an unpinned k-medians solver
//! supports the "globally optimal but no endpoints" ablation.

use crate::codes::code::Code;
use crate::dist::Dist1D;
use crate::numerics::roots::brent;

const RHO_EPS: f64 = 1e-9;

/// Run the Eq.-5 recursion from (a_prev, a_cur) for `steps` steps.
/// Returns the full chain [a_prev, a_cur, ...] or None if a ρ leaves (0,1)
/// or monotonicity breaks (the shot is infeasible).
fn forward_chain(
    dist: &dyn Dist1D,
    a_prev: f64,
    a_cur: f64,
    steps: usize,
) -> Option<Vec<f64>> {
    let mut chain = Vec::with_capacity(steps + 2);
    chain.push(a_prev);
    chain.push(a_cur);
    let (mut prev, mut cur) = (a_prev, a_cur);
    for _ in 0..steps {
        let rho = 2.0 * dist.cdf(cur) - dist.cdf(0.5 * (prev + cur));
        if !(RHO_EPS..=1.0 - RHO_EPS).contains(&rho) {
            return None;
        }
        let next = 2.0 * dist.quantile(rho) - cur;
        if next <= cur + 1e-12 {
            return None;
        }
        chain.push(next);
        prev = cur;
        cur = next;
    }
    Some(chain)
}

/// Shooting residual: where the recursion lands after `steps` steps starting
/// from (start, a2), minus `target`. Infeasible shots get a large signed
/// penalty so bracketing still works (too-big ρ ⇒ overshoot ⇒ positive).
fn shoot(dist: &dyn Dist1D, start: f64, a2: f64, steps: usize, target: f64) -> f64 {
    match forward_chain(dist, start, a2, steps) {
        Some(chain) => chain[chain.len() - 1] - target,
        None => {
            // Diagnose the direction of failure: rerun and see if rho
            // clipped high (overshoot) or low/non-monotone (undershoot).
            let (mut prev, mut cur) = (start, a2);
            for _ in 0..steps {
                let rho = 2.0 * dist.cdf(cur) - dist.cdf(0.5 * (prev + cur));
                if rho >= 1.0 - RHO_EPS {
                    return 1e6;
                }
                if rho <= RHO_EPS {
                    return -1e6;
                }
                let next = 2.0 * dist.quantile(rho) - cur;
                if next <= cur + 1e-12 {
                    return -1e6;
                }
                prev = cur;
                cur = next;
            }
            unreachable!("forward_chain failed but rerun succeeded");
        }
    }
}

/// Solve one half by shooting: find a2 ∈ (lo_open, hi_open) such that the
/// recursion from (start, a2) lands on `target` after `steps` steps.
/// Grid-scan for a sign change, then Brent.
fn solve_half(
    dist: &dyn Dist1D,
    start: f64,
    lo_open: f64,
    hi_open: f64,
    steps: usize,
    target: f64,
) -> Vec<f64> {
    let n_grid = 400;
    let mut prev_x = f64::NAN;
    let mut prev_f = f64::NAN;
    let mut bracket = None;
    for i in 1..n_grid {
        let x = lo_open + (hi_open - lo_open) * i as f64 / n_grid as f64;
        let fx = shoot(dist, start, x, steps, target);
        if i > 1 && prev_f.is_finite() && fx.is_finite() && prev_f * fx <= 0.0 {
            bracket = Some((prev_x, x));
            break;
        }
        prev_x = x;
        prev_f = fx;
    }
    let (blo, bhi) = bracket.unwrap_or_else(|| {
        panic!(
            "AF4 shooting: no bracket for start={start} target={target} steps={steps}"
        )
    });
    let root = brent(
        |x| shoot(dist, start, x, steps, target),
        blo,
        bhi,
        1e-13,
        200,
    )
    .expect("bracketed root");
    let mut chain = forward_chain(dist, start, root.x, steps)
        .expect("root of shoot() must be feasible");
    // Snap the landing point exactly onto the target (it is pinned).
    let last = chain.len() - 1;
    chain[last] = target;
    chain
}

/// Construct the pinned L1-optimal 16-value code for an arbitrary
/// distribution (pinned at −1, 0, +1 like AF4). This is the paper's §4.2
/// machinery in its general form.
pub fn l1_pinned_code(dist: &dyn Dist1D, name: &str) -> Code {
    // Lower half: a1 = -1 … a8 = 0 (recursion makes a3..a8: 6 steps).
    let lower = solve_half(dist, -1.0, -1.0 + 1e-6, -1e-6, 6, 0.0);
    // Upper half: a8 = 0 … a16 = 1 (recursion makes a10..a16: 7 steps).
    let upper = solve_half(dist, 0.0, 1e-6, 1.0 - 1e-6, 7, 1.0);
    let mut values = lower;
    values.extend_from_slice(&upper[1..]); // skip duplicate 0
    debug_assert_eq!(values.len(), 16);
    Code::new(name, values)
}

/// AF4-B: the paper's code — pinned L1-optimal under `F_X(·; B)`.
pub fn af4(b: usize) -> Code {
    let dist = crate::dist::BlockScaledDist::new(b);
    l1_pinned_code(&dist, &format!("af4-{b}"))
}

/// Pinned Lloyd (median) iteration — independent cross-check of the
/// shooting solver. Free values update to the median of their bin; pinned
/// indices stay fixed. Converges linearly; we run to `tol` drift.
pub fn l1_pinned_lloyd(dist: &dyn Dist1D, init: &[f64], pinned: &[usize], tol: f64) -> Vec<f64> {
    let mut a = init.to_vec();
    let k = a.len();
    for _ in 0..10_000 {
        let mut drift = 0.0f64;
        let bounds: Vec<f64> = a.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
        for j in 0..k {
            if pinned.contains(&j) {
                continue;
            }
            let lo_p = if j == 0 { 0.0 } else { dist.cdf(bounds[j - 1]) };
            let hi_p = if j == k - 1 { 1.0 } else { dist.cdf(bounds[j]) };
            let target = 0.5 * (lo_p + hi_p);
            let new = dist.quantile(target.clamp(1e-12, 1.0 - 1e-12));
            drift = drift.max((new - a[j]).abs());
            a[j] = new;
        }
        if drift < tol {
            break;
        }
    }
    a
}

/// Unpinned k-medians via Lloyd iteration (ablation #1: what the globally
/// L1-optimal code looks like without the −1/0/+1 pins).
pub fn kmedians_unpinned(dist: &dyn Dist1D, k: usize, name: &str) -> Code {
    // Init at evenly spaced quantiles.
    let init: Vec<f64> = (0..k)
        .map(|j| dist.quantile(((j as f64 + 0.5) / k as f64).clamp(1e-9, 1.0 - 1e-9)))
        .collect();
    // Dedup safety: nudge collisions (atoms can make quantiles coincide).
    let mut init = init;
    for j in 1..k {
        if init[j] <= init[j - 1] + 1e-9 {
            init[j] = init[j - 1] + 1e-6;
        }
    }
    let vals = l1_pinned_lloyd(dist, &init, &[], 1e-12);
    Code::new(name, vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::error::expected_l1;
    use crate::dist::{BlockScaledDist, ScaledNormal};

    #[test]
    fn af4_structure() {
        let c = af4(64);
        assert_eq!(c.k(), 16);
        assert!(c.has_endpoints_and_zero());
        assert_eq!(c.values[0], -1.0);
        assert_eq!(c.values[7], 0.0);
        assert_eq!(c.values[15], 1.0);
        for w in c.values.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn af4_satisfies_median_stationarity() {
        // Eq. 4: P[mid(a_{j-1},a_j) < Y < a_j] == P[a_j < Y < mid(a_j,a_{j+1})]
        let b = 64;
        let dist = BlockScaledDist::new(b);
        let c = af4(b);
        let a = &c.values;
        for j in 1..15 {
            if j == 7 {
                continue; // a8 = 0 is pinned, not stationary
            }
            let left = dist.cdf(a[j]) - dist.cdf(0.5 * (a[j - 1] + a[j]));
            let right = dist.cdf(0.5 * (a[j] + a[j + 1])) - dist.cdf(a[j]);
            assert!(
                (left - right).abs() < 1e-6,
                "stationarity fails at j={j}: {left} vs {right}"
            );
        }
    }

    #[test]
    fn af4_concentrates_with_block_size() {
        // Fig. 1: interior values shrink toward 0 as B grows.
        let c64 = af4(64);
        let c1024 = af4(1024);
        let c4096 = af4(4096);
        for j in [2usize, 5, 10, 13] {
            assert!(
                c1024.values[j].abs() < c64.values[j].abs(),
                "j={j}: {} !< {}",
                c1024.values[j],
                c64.values[j]
            );
            assert!(c4096.values[j].abs() < c1024.values[j].abs(), "j={j}");
        }
    }

    #[test]
    fn af4_64_outer_values_near_nf4() {
        // Paper §5: "the outermost NF4 values happen to nearly coincide with
        // AF4-64".
        let a = af4(64);
        let n = crate::codes::nf4::nf4();
        assert!((a.values[1] - n.values[1]).abs() < 0.06, "{} vs {}", a.values[1], n.values[1]);
        assert!((a.values[14] - n.values[14]).abs() < 0.06, "{} vs {}", a.values[14], n.values[14]);
    }

    #[test]
    fn lloyd_agrees_with_shooting() {
        let dist = BlockScaledDist::new(64);
        let c = af4(64);
        let refined = l1_pinned_lloyd(&dist, &c.values, &[0, 7, 15], 1e-10);
        for (s, l) in c.values.iter().zip(&refined) {
            assert!((s - l).abs() < 1e-5, "shooting {s} vs lloyd {l}");
        }
    }

    #[test]
    fn pinning_worsens_expected_l1() {
        // Paper §5: AF4 is NOT the global optimum; requiring −1/0/+1 makes
        // expected reconstruction error worse.
        let dist = BlockScaledDist::new(64);
        let pinned = af4(64);
        let free = kmedians_unpinned(&dist, 16, "kmed-64");
        let e_pinned = expected_l1(&pinned, &dist);
        let e_free = expected_l1(&free, &dist);
        assert!(
            e_free < e_pinned,
            "unpinned {e_free} should beat pinned {e_pinned}"
        );
    }

    #[test]
    fn af4_beats_nf4_on_expected_l1_large_b() {
        // The whole point of AF4: lower expected L1 error under F_X(·;B),
        // dramatically so at large B.
        let b = 4096;
        let dist = BlockScaledDist::new(b);
        let a = af4(b);
        let n = crate::codes::nf4::nf4();
        let ea = expected_l1(&a, &dist);
        let en = expected_l1(&n, &dist);
        assert!(ea < en * 0.97, "AF4 {ea} should beat NF4 {en} at B={b}");
    }

    #[test]
    fn pinned_solver_works_on_plain_normal() {
        // Generic-distribution path: scaled normal (no atoms).
        let d = ScaledNormal::nf4_implied();
        let c = l1_pinned_code(&d, "l1-normal");
        assert_eq!(c.k(), 16);
        assert!(c.has_endpoints_and_zero());
    }

    #[test]
    fn kmedians_unpinned_is_stationary() {
        let dist = BlockScaledDist::new(256);
        let c = kmedians_unpinned(&dist, 16, "kmed");
        let a = &c.values;
        for j in 1..15 {
            let left = dist.cdf(a[j]) - dist.cdf(0.5 * (a[j - 1] + a[j]));
            let right = dist.cdf(0.5 * (a[j] + a[j + 1])) - dist.cdf(a[j]);
            assert!((left - right).abs() < 1e-6, "j={j}");
        }
    }
}
