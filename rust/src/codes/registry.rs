//! Name-based code construction and (de)serialization.
//!
//! Spec grammar (used by the CLI, config files, and the experiment harness):
//!
//! - `nf4`              — canonical NF4
//! - `nf4-avgq`         — §4 "average of quantiles" variant
//! - `af4-<B>`          — AF4 with block size B (e.g. `af4-64`)
//! - `af4x-<B>`         — AF4 built on the Appendix-A approximate CDF
//! - `balanced-<B>`     — §4.1 uniform-usage code for block size B
//! - `balanced-ep-<B>`  — Appendix-B variant with −1/0/+1 grafted in
//! - `kmedians-<B>`     — unpinned global k-medians (ablation)
//! - `normal-l1`        — pinned L1 code on the NF4-implied scaled normal
//! - `fp`               — sentinel for "no quantization" (not a Code)
//!
//! Codes are built **at most once per spec** and shared as `Arc<Code>`:
//! AF4 construction is quadrature-heavy root finding (~10 ms) and the
//! router prepares many (model × code × B) services concurrently, so the
//! cache is a per-spec [`OnceLock`] slot — two threads racing on the same
//! unseen spec block on one construction instead of both computing it,
//! while different specs construct in parallel. Callers share the cached
//! `Arc` (no per-request heap clone of the table).

use crate::codes::af4::{af4, kmedians_unpinned, l1_pinned_code};
use crate::codes::balanced::{balanced, balanced_with_endpoints};
use crate::codes::code::Code;
use crate::codes::nf4::{nf4, nf4_avg_quantiles};
use crate::dist::{ApproxBlockDist, BlockScaledDist, ScaledNormal};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// One cache slot per spec. The map lock is held only to fetch/insert the
/// slot; construction itself runs under the slot's `OnceLock`, so a slow
/// build of one spec never serializes builds of other specs.
type Slot = Arc<OnceLock<Option<Arc<Code>>>>;

static CACHE: Mutex<Option<HashMap<String, Slot>>> = Mutex::new(None);

/// Per-spec construction tally (how many times `construct` actually ran).
/// Test-only instrumentation for asserting the at-most-once contract under
/// contention; compiled out of production builds.
#[cfg(test)]
static BUILT: Mutex<Option<HashMap<String, usize>>> = Mutex::new(None);

/// Is this spec the "no quantization" sentinel?
pub fn is_fp(spec: &str) -> bool {
    matches!(spec, "fp" | "fp32" | "none")
}

/// Build (or fetch from cache) the code named by `spec`. Returns None for
/// unknown specs and for the `fp` sentinel. Construction happens at most
/// once per spec across all threads; the returned `Arc` is shared with
/// every other caller of the same spec.
pub fn build(spec: &str) -> Option<Arc<Code>> {
    if is_fp(spec) {
        return None;
    }
    let slot: Slot = {
        let mut guard = CACHE.lock().unwrap();
        let map = guard.get_or_insert_with(HashMap::new);
        Arc::clone(map.entry(spec.to_string()).or_insert_with(|| Arc::new(OnceLock::new())))
    };
    slot.get_or_init(|| {
        let code = construct(spec);
        if code.is_some() {
            // Registry tally of actual constructions (never cache hits):
            // quadrature-heavy builds showing up here more than once per
            // spec per process would mean the memo broke.
            constructions_total().inc(1);
            #[cfg(test)]
            {
                let mut guard = BUILT.lock().unwrap();
                *guard
                    .get_or_insert_with(HashMap::new)
                    .entry(spec.to_string())
                    .or_insert(0) += 1;
            }
        }
        code.map(Arc::new)
    })
    .clone()
}

/// Process-wide count of code constructions, mirrored into the metrics
/// registry as `afq_codes_registry_constructions_total`.
fn constructions_total() -> &'static crate::obs::registry::Counter {
    static C: OnceLock<crate::obs::registry::Counter> = OnceLock::new();
    C.get_or_init(|| crate::obs::registry::counter("afq_codes_registry_constructions_total"))
}

/// How many times `spec` has actually been constructed (not cache hits).
/// The at-most-once contract means this never exceeds 1 per process.
#[cfg(test)]
pub(crate) fn construction_count(spec: &str) -> usize {
    BUILT.lock().unwrap().as_ref().and_then(|m| m.get(spec).copied()).unwrap_or(0)
}

fn parse_block(spec: &str, prefix: &str) -> Option<usize> {
    spec.strip_prefix(prefix)?.parse().ok()
}

/// Block-scaled constructions need B ≥ 2 — `F_X(·; B)` is undefined below
/// that, and `BlockScaledDist::new` panics. Reject degenerate specs like
/// `af4-0` or `balanced-ep-1` here, at parse time, with a loud warning
/// instead of handing the dist layer a B it will assert on.
fn valid_block(spec: &str, b: usize) -> Option<usize> {
    if b >= 2 {
        Some(b)
    } else {
        crate::log_warn!("code spec {spec:?} rejected: block size {b} < 2");
        None
    }
}

/// Is this one of the family names [`for_block_size`] resolves (not a
/// literal spec like `af4-64`, which resolves through `build` directly)?
fn known_family(family: &str) -> bool {
    matches!(
        family,
        "nf4" | "nf4-avgq" | "normal-l1" | "af4" | "af4x" | "balanced" | "balanced-ep"
            | "kmedians"
    )
}

/// A clear message for why `(family, b)` cannot be built — distinguishes a
/// degenerate block size on a KNOWN family from a genuinely unknown
/// family (blaming the block size on a family that doesn't exist sends
/// the user fixing the wrong thing). Used by the service/planner layers
/// when `build`/`for_block_size` return None.
pub fn describe_build_failure(family: &str, b: usize) -> String {
    if known_family(family) && b < 2 && !is_fp(family) {
        format!(
            "invalid block size {b} for code family {family:?}: block-scaled codes need B ≥ 2"
        )
    } else if b < 2 && !is_fp(family) {
        format!("unknown code family {family:?} (block size {b} is also invalid: need B ≥ 2)")
    } else {
        format!("unknown code family {family:?}")
    }
}

fn construct(spec: &str) -> Option<Code> {
    match spec {
        "nf4" => Some(nf4()),
        "nf4-avgq" => Some(nf4_avg_quantiles()),
        "normal-l1" => {
            let d = ScaledNormal::nf4_implied();
            Some(l1_pinned_code(&d, "normal-l1"))
        }
        _ => {
            if let Some(b) = parse_block(spec, "af4-") {
                Some(af4(valid_block(spec, b)?))
            } else if let Some(b) = parse_block(spec, "af4x-") {
                let d = ApproxBlockDist::new(valid_block(spec, b)?);
                Some(l1_pinned_code(&d, spec))
            } else if let Some(b) = parse_block(spec, "balanced-ep-") {
                let d = BlockScaledDist::new(valid_block(spec, b)?);
                Some(balanced_with_endpoints(&d, 16, spec))
            } else if let Some(b) = parse_block(spec, "balanced-") {
                let d = BlockScaledDist::new(valid_block(spec, b)?);
                Some(balanced(&d, 16, spec))
            } else if let Some(b) = parse_block(spec, "kmedians-") {
                let d = BlockScaledDist::new(valid_block(spec, b)?);
                Some(kmedians_unpinned(&d, 16, spec))
            } else {
                None
            }
        }
    }
}

/// Resolve the code to use for quantizing at block size `b` given a family
/// name: `af4` → `af4-<b>` (block-size-adaptive, the paper's point), others
/// are block-size-independent.
pub fn for_block_size(family: &str, b: usize) -> Option<Arc<Code>> {
    match family {
        "af4" => build(&format!("af4-{b}")),
        "af4x" => build(&format!("af4x-{b}")),
        "balanced" => build(&format!("balanced-{b}")),
        "balanced-ep" => build(&format!("balanced-ep-{b}")),
        "kmedians" => build(&format!("kmedians-{b}")),
        other => build(other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_all_families() {
        for spec in [
            "nf4",
            "nf4-avgq",
            "af4-64",
            "af4x-64",
            "balanced-64",
            "balanced-ep-64",
            "kmedians-64",
            "normal-l1",
        ] {
            let c = build(spec).unwrap_or_else(|| panic!("spec {spec}"));
            assert_eq!(c.k(), 16, "{spec}");
        }
    }

    #[test]
    fn fp_sentinel_and_unknown() {
        assert!(build("fp").is_none());
        assert!(is_fp("fp32"));
        assert!(build("bogus-123").is_none());
        assert!(build("af4-").is_none());
    }

    #[test]
    fn degenerate_block_sizes_rejected() {
        // B < 2 used to parse and panic inside BlockScaledDist::new; now
        // every block-scaled family rejects it at spec-parse time.
        for spec in ["af4-0", "af4-1", "af4x-1", "balanced-ep-0", "balanced-1", "kmedians-0"] {
            assert!(build(spec).is_none(), "{spec} must not build");
        }
        let msg = describe_build_failure("af4", 0);
        assert!(msg.contains("B ≥ 2"), "{msg}");
        assert!(describe_build_failure("bogus", 64).contains("unknown"));
        // An unknown family is diagnosed as unknown even with a bad B —
        // never as a block-size problem on a family that doesn't exist.
        let both = describe_build_failure("bogus", 0);
        assert!(both.contains("unknown") && both.contains("B ≥ 2"), "{both}");
    }

    #[test]
    fn cache_returns_shared_arc() {
        let a = build("af4-128").unwrap();
        let b = build("af4-128").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second build must be the cached Arc");
        assert_eq!(a, b);
    }

    #[test]
    fn concurrent_builds_construct_at_most_once() {
        // af4-96 is quadrature-heavy and used by no other test, so the
        // per-spec tally below is deterministic even with the test harness
        // running modules in parallel.
        let spec = "af4-96";
        let codes: Vec<Arc<Code>> = std::thread::scope(|s| {
            let joins: Vec<_> =
                (0..8).map(|_| s.spawn(|| build(spec).expect("af4-96 builds"))).collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(construction_count(spec), 1, "racing builds must construct once");
        for c in &codes[1..] {
            assert!(Arc::ptr_eq(&codes[0], c), "all racers share one allocation");
        }
        let total =
            crate::obs::registry::counter("afq_codes_registry_constructions_total").get();
        assert!(total >= 1, "registry mirrors construction tallies: {total}");
    }

    #[test]
    fn family_resolution_adapts_af4() {
        let a64 = for_block_size("af4", 64).unwrap();
        let a1024 = for_block_size("af4", 1024).unwrap();
        assert_ne!(a64.values, a1024.values);
        let n1 = for_block_size("nf4", 64).unwrap();
        let n2 = for_block_size("nf4", 1024).unwrap();
        assert_eq!(n1.values, n2.values);
    }

    #[test]
    fn approx_af4_close_to_exact() {
        // Ablation #3: Appendix-A CDF is near-exact, so the codes should be
        // close (but not identical).
        let exact = build("af4-64").unwrap();
        let approx = build("af4x-64").unwrap();
        let max_diff = exact
            .values
            .iter()
            .zip(&approx.values)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_diff < 0.02, "approx should track exact: {max_diff}");
    }
}
