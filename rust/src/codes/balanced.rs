//! Uniform-usage ("balanced") codes — §4.1 and Appendix B.
//!
//! Bins with equal probability mass under a distribution F are
//! `b_k = F⁻¹((k−1)/K)`, k = 1…K+1. A code whose bin *boundaries* are
//! exactly these points is built by the paper's recursion
//!
//! ```text
//! choose q₁ ∈ [b₁, b₂];   q_k = 2·b_k − q_{k−1}   (k = 2 … K)
//! ```
//!
//! which forces each midpoint (q_{k−1}+q_k)/2 = b_k. The free choice of q₁
//! yields a one-parameter family (Fig. 11); not all choices remain valid
//! (each q_k must stay inside its bin and be monotone), so construction
//! reports validity.
//!
//! Appendix B's "Balanced w/ endpoints" variant grafts −1, 0, +1 into the
//! balanced code (the paper shows this is *necessary* for acceptable LM
//! quality, even though it breaks exact uniformity).

use crate::codes::code::Code;
use crate::dist::Dist1D;

/// Equal-mass bin boundaries `b_1..b_{K+1}` for K bins under `dist`.
/// With the block-scaled mixture, `b_1 = −1` and `b_{K+1} = 1` (the atoms'
/// locations), matching the paper's use.
pub fn equal_mass_boundaries(dist: &dyn Dist1D, k: usize) -> Vec<f64> {
    let (lo, hi) = dist.support();
    let mut b = Vec::with_capacity(k + 1);
    b.push(lo);
    for i in 1..k {
        b.push(dist.quantile(i as f64 / k as f64));
    }
    b.push(hi);
    b
}

/// Build the balanced code for a given q₁. Returns the values and whether
/// the construction stayed valid (monotone, each q_k within its bin).
pub fn balanced_from_q1(dist: &dyn Dist1D, k: usize, q1: f64) -> (Vec<f64>, bool) {
    let b = equal_mass_boundaries(dist, k);
    let mut q = Vec::with_capacity(k);
    q.push(q1);
    let mut valid = (b[0]..=b[1]).contains(&q1);
    for j in 1..k {
        let next = 2.0 * b[j] - q[j - 1];
        if next <= q[j - 1] || !(b[j]..=b[j + 1]).contains(&next) {
            valid = false;
        }
        q.push(next);
    }
    (q, valid)
}

/// The feasible interval of q₁ values that produce a fully valid balanced
/// code, found by scanning. Returns None if the family is empty.
pub fn feasible_q1_range(dist: &dyn Dist1D, k: usize, scan: usize) -> Option<(f64, f64)> {
    let b = equal_mass_boundaries(dist, k);
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for i in 0..=scan {
        let q1 = b[0] + (b[1] - b[0]) * i as f64 / scan as f64;
        let (_, ok) = balanced_from_q1(dist, k, q1);
        if ok {
            lo = lo.min(q1);
            hi = hi.max(q1);
        }
    }
    if lo.is_finite() {
        Some((lo, hi))
    } else {
        None
    }
}

/// The canonical balanced code: q₁ at the midpoint of the feasible range
/// (the paper picks representatives of the family; midpoint is a stable,
/// reproducible choice).
pub fn balanced(dist: &dyn Dist1D, k: usize, name: &str) -> Code {
    let (lo, hi) =
        feasible_q1_range(dist, k, 2000).expect("balanced family should be nonempty");
    let (vals, ok) = balanced_from_q1(dist, k, 0.5 * (lo + hi));
    assert!(ok, "midpoint of feasible range must be valid");
    Code::new(name, vals)
}

/// "Balanced w/ endpoints" (Appendix B / Fig. 12): take the balanced code
/// and graft in −1, 0, +1 by replacing the first value, the value nearest
/// zero, and the last value.
pub fn balanced_with_endpoints(dist: &dyn Dist1D, k: usize, name: &str) -> Code {
    let base = balanced(dist, k, "tmp");
    let mut vals = base.values.clone();
    let n = vals.len();
    vals[0] = -1.0;
    vals[n - 1] = 1.0;
    // nearest-to-zero index
    let zi = vals
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.abs().partial_cmp(&b.abs()).unwrap())
        .unwrap()
        .0;
    vals[zi] = 0.0;
    Code::new(name, vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::BlockScaledDist;
    use crate::util::rng::Rng;

    #[test]
    fn boundaries_are_equal_mass() {
        let dist = BlockScaledDist::new(64);
        let b = equal_mass_boundaries(&dist, 16);
        assert_eq!(b.len(), 17);
        assert_eq!(b[0], -1.0);
        assert_eq!(b[16], 1.0);
        for i in 1..16 {
            let mass = dist.cdf(b[i]);
            assert!(
                (mass - i as f64 / 16.0).abs() < 1e-8,
                "boundary {i}: mass {mass}"
            );
        }
    }

    #[test]
    fn recursion_places_midpoints_on_boundaries() {
        let dist = BlockScaledDist::new(64);
        let b = equal_mass_boundaries(&dist, 16);
        let code = balanced(&dist, 16, "bal");
        for j in 1..16 {
            let mid = 0.5 * (code.values[j - 1] + code.values[j]);
            assert!(
                (mid - b[j]).abs() < 1e-10,
                "midpoint {j}: {mid} vs boundary {}",
                b[j]
            );
        }
    }

    #[test]
    fn balanced_usage_is_uniform() {
        // The defining property (Fig. 12 "Balanced"): each code value is
        // used with probability 1/16, verified by Monte Carlo.
        let b = 64;
        let dist = BlockScaledDist::new(b);
        let code = balanced(&dist, 16, "bal");
        let mut rng = Rng::new(31);
        let xs = dist.sample(&mut rng, 4096);
        let usage = code.usage(&xs);
        for (j, &u) in usage.iter().enumerate() {
            assert!(
                (u - 1.0 / 16.0).abs() < 0.012,
                "bin {j} usage {u} should be ~0.0625"
            );
        }
    }

    #[test]
    fn family_is_nondegenerate() {
        // Fig. 11: a genuine 1-parameter family exists for B=64.
        let dist = BlockScaledDist::new(64);
        let (lo, hi) = feasible_q1_range(&dist, 16, 2000).unwrap();
        assert!(hi > lo, "feasible range should be an interval: [{lo}, {hi}]");
        let (v1, ok1) = balanced_from_q1(&dist, 16, lo + 0.25 * (hi - lo));
        let (v2, ok2) = balanced_from_q1(&dist, 16, lo + 0.75 * (hi - lo));
        assert!(ok1 && ok2);
        assert!((v1[5] - v2[5]).abs() > 1e-6, "different q1 ⇒ different codes");
    }

    #[test]
    fn invalid_q1_detected() {
        let dist = BlockScaledDist::new(64);
        let b = equal_mass_boundaries(&dist, 16);
        // q1 at the very left edge tends to push later values out of bins.
        let (_, ok_edge) = balanced_from_q1(&dist, 16, b[0]);
        let (lo, hi) = feasible_q1_range(&dist, 16, 2000).unwrap();
        let (_, ok_mid) = balanced_from_q1(&dist, 16, 0.5 * (lo + hi));
        assert!(ok_mid);
        // At least one of the extremes must be infeasible, otherwise the
        // whole bin is feasible and the family check above still holds.
        let (_, ok_right) = balanced_from_q1(&dist, 16, b[1]);
        assert!(!ok_edge || !ok_right, "expected some infeasible q1");
    }

    #[test]
    fn endpoints_variant_has_the_essential_values() {
        let dist = BlockScaledDist::new(4096);
        let c = balanced_with_endpoints(&dist, 16, "bal-ep");
        assert!(c.has_endpoints_and_zero());
        assert_eq!(c.k(), 16);
    }

    #[test]
    fn endpoints_variant_less_uniform_than_balanced() {
        // Fig. 12's message: grafting endpoints breaks exact uniformity.
        let b = 4096;
        let dist = BlockScaledDist::new(b);
        let bal = balanced(&dist, 16, "bal");
        let ep = balanced_with_endpoints(&dist, 16, "bal-ep");
        let mut rng = Rng::new(41);
        let xs = dist.sample(&mut rng, 2048);
        let spread = |u: &[f64]| {
            let mx = u.iter().cloned().fold(0.0f64, f64::max);
            let mn = u.iter().cloned().fold(1.0f64, f64::min);
            mx - mn
        };
        let s_bal = spread(&bal.usage(&xs));
        let s_ep = spread(&ep.usage(&xs));
        assert!(s_ep > s_bal, "endpoints should hurt uniformity: {s_ep} vs {s_bal}");
    }

    #[test]
    fn balanced_usage_uniform_even_at_large_b() {
        // §4.1 works for any B — construction must adapt to B=4096 where the
        // distribution is heavily concentrated.
        let dist = BlockScaledDist::new(4096);
        let code = balanced(&dist, 16, "bal-4096");
        let mut rng = Rng::new(53);
        let xs = dist.sample(&mut rng, 512);
        let usage = code.usage(&xs);
        for &u in &usage {
            assert!((u - 1.0 / 16.0).abs() < 0.02, "usage {u}");
        }
    }
}
