//! Memoized predicted reconstruction error per `(code, block size)` —
//! the quantity the planner ([`crate::plan`]) minimizes.
//!
//! `expected_l1(code, BlockScaledDist::new(b))` is quadrature over a
//! distribution whose own memo table is quadrature to build: a single cold
//! evaluation costs milliseconds. The planner evaluates the *same*
//! `(code, B)` pairs across every tensor of a model (and again for every
//! budget in a sweep), so results are cached process-wide, keyed by
//! `(code name, B)` — the dist parameter is exactly `B`, so that pair
//! fully determines both functionals. Both L1 and L2 are computed on one
//! miss (they share the dist construction, the expensive part).
//!
//! Same slot pattern as [`crate::codes::registry`]: the map lock is held
//! only to fetch/insert a slot; the quadrature runs under the slot's
//! `OnceLock`, so two threads racing on one cold pair compute it once
//! while different pairs evaluate in parallel.
//!
//! [`cache_counts_for`] exposes per-key (hits, misses) so tests can assert
//! the at-most-once contract without racing other tests' queries;
//! [`cache_counts`] sums them. The tallies are a tiny map update under the
//! same lock the slot fetch already takes, and stay compiled in.

use crate::codes::error::{expected_l1, expected_l2};
use crate::codes::registry;
use crate::dist::BlockScaledDist;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

type Slot = Arc<OnceLock<(f64, f64)>>;

static CACHE: Mutex<Option<HashMap<(String, usize), Slot>>> = Mutex::new(None);
/// Per-key (hits, misses) tallies. A hit = the slot already existed when
/// queried (quadrature skipped).
static STATS: Mutex<Option<HashMap<(String, usize), (u64, u64)>>> = Mutex::new(None);

fn bump(key: &(String, usize), hit: bool) {
    let mut guard = STATS.lock().unwrap();
    let entry = guard.get_or_insert_with(HashMap::new).entry(key.clone()).or_insert((0, 0));
    if hit {
        entry.0 += 1;
        global_tallies().hits.inc(1);
    } else {
        entry.1 += 1;
        global_tallies().misses.inc(1);
    }
}

struct MemoTallies {
    hits: crate::obs::registry::Counter,
    misses: crate::obs::registry::Counter,
}

/// Registry mirror of the aggregate memo tallies (resolved once; bumps
/// are relaxed atomic adds). Per-key counts stay in `STATS` for tests.
fn global_tallies() -> &'static MemoTallies {
    static T: OnceLock<MemoTallies> = OnceLock::new();
    T.get_or_init(|| MemoTallies {
        hits: crate::obs::registry::counter("afq_codes_predict_memo_hits_total"),
        misses: crate::obs::registry::counter("afq_codes_predict_memo_misses_total"),
    })
}

/// Predicted (E|err|, E err²) of quantizing `F_X(·; B)` with the code the
/// registry resolves for `(family, b)` — memoized per `(code name, b)`.
///
/// Returns `Some((0, 0))` for the `fp` sentinel (no quantization, no
/// error) and `None` for unknown families or degenerate block sizes.
pub fn predicted_errors(family: &str, b: usize) -> Option<(f64, f64)> {
    if registry::is_fp(family) {
        return Some((0.0, 0.0));
    }
    if b < 2 {
        return None;
    }
    // Resolve the code first: block-size-adaptive families (`af4`) map to
    // different codes per B, fixed codes (`nf4`) to one — the cache key is
    // the *resolved* code name plus the dist parameter B, so `af4@64` and
    // a literal `af4-64@64` share one entry.
    let code = registry::for_block_size(family, b)?;
    let key = (code.name.clone(), b);
    let (slot, pre_existing): (Slot, bool) = {
        let mut guard = CACHE.lock().unwrap();
        let map = guard.get_or_insert_with(HashMap::new);
        match map.get(&key) {
            Some(s) => (Arc::clone(s), true),
            None => {
                let s: Slot = Arc::new(OnceLock::new());
                map.insert(key, Arc::clone(&s));
                (s, false)
            }
        }
    };
    if pre_existing {
        bump(&key, true);
    }
    let (l1, l2) = *slot.get_or_init(|| {
        bump(&key, false);
        let dist = BlockScaledDist::new(b);
        (expected_l1(&code, &dist), expected_l2(&code, &dist))
    });
    Some((l1, l2))
}

/// Predicted per-element L1 error for `(family, b)` — the planner's
/// objective term. See [`predicted_errors`].
pub fn predicted_l1(family: &str, b: usize) -> Option<f64> {
    predicted_errors(family, b).map(|(l1, _)| l1)
}

/// (hits, misses) for one `(code name, B)` key — the cache key is the
/// *resolved* code name (`af4-64`, not `af4`) plus the block size.
pub fn cache_counts_for(code_name: &str, b: usize) -> (u64, u64) {
    STATS
        .lock()
        .unwrap()
        .as_ref()
        .and_then(|m| m.get(&(code_name.to_string(), b)).copied())
        .unwrap_or((0, 0))
}

/// Cumulative (hits, misses) across the whole process-wide table.
pub fn cache_counts() -> (u64, u64) {
    STATS
        .lock()
        .unwrap()
        .as_ref()
        .map(|m| {
            m.values().fold((0, 0), |(h, mi), &(kh, km)| (h + kh, mi + km))
        })
        .unwrap_or((0, 0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_queries_hit_the_cache() {
        // (nf4-avgq, 48) is used by no other test; per-key tallies make
        // the assertions immune to parallel tests hitting other keys.
        let first = predicted_errors("nf4-avgq", 48).expect("builds");
        assert_eq!(cache_counts_for("nf4-avgq", 48), (0, 1), "first query computes");
        for _ in 0..5 {
            assert_eq!(predicted_errors("nf4-avgq", 48).unwrap(), first);
        }
        assert_eq!(
            cache_counts_for("nf4-avgq", 48),
            (5, 1),
            "repeats must hit, never recompute"
        );
        let (h, m) = cache_counts();
        assert!(h >= 5 && m >= 1, "global tallies fold the per-key counts");
        let reg_hits =
            crate::obs::registry::counter("afq_codes_predict_memo_hits_total").get();
        let reg_misses =
            crate::obs::registry::counter("afq_codes_predict_memo_misses_total").get();
        assert!(reg_hits >= 5 && reg_misses >= 1, "registry mirrors the tallies");
        // Concurrent cold queries on one fresh key construct at most once.
        std::thread::scope(|s| {
            let joins: Vec<_> = (0..6)
                .map(|_| s.spawn(|| predicted_errors("nf4-avgq", 56).unwrap()))
                .collect();
            let vals: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
            assert!(vals.windows(2).all(|w| w[0] == w[1]));
        });
        let (_, m56) = cache_counts_for("nf4-avgq", 56);
        assert_eq!(m56, 1, "racing cold queries compute once");
    }

    #[test]
    fn matches_uncached_functionals() {
        let (l1, l2) = predicted_errors("nf4", 32).unwrap();
        let dist = BlockScaledDist::new(32);
        let code = registry::build("nf4").unwrap();
        assert_eq!(l1, expected_l1(&code, &dist));
        assert_eq!(l2, expected_l2(&code, &dist));
        assert!(l1 > 0.0 && l2 > 0.0 && l2 < l1, "4-bit code on [-1,1]: {l1} {l2}");
    }

    #[test]
    fn fp_and_invalid_specs() {
        assert_eq!(predicted_errors("fp", 64), Some((0.0, 0.0)));
        assert_eq!(predicted_errors("fp32", 0), Some((0.0, 0.0)));
        assert_eq!(predicted_errors("bogus", 64), None);
        assert_eq!(predicted_errors("nf4", 1), None);
        assert_eq!(predicted_l1("nf4", 0), None);
    }

    #[test]
    fn adaptive_family_tracks_block_size() {
        // The paper's point, through the table: AF4 adapts to B and beats
        // NF4 at large block sizes.
        let nf4 = predicted_l1("nf4", 4096).unwrap();
        let af4 = predicted_l1("af4", 4096).unwrap();
        assert!(af4 < nf4, "af4 {af4} vs nf4 {nf4} at B=4096");
    }
}
