//! NF4 — the NormalFloat-4 code of Dettmers et al. (2023), §2 of the paper.
//!
//! Construction (quantile-of-averaged-probabilities, the bitsandbytes
//! `create_normal_map` variant):
//!
//! 1. δ = ½(1/32 + 1/30)
//! 2. negative half: 8 evenly spaced probabilities p₁ = δ … p₈ = ½,
//!    q̃ᵢ = Φ⁻¹(pᵢ)        (q̃₈ = 0)
//! 3. positive half: 9 evenly spaced r₈ = ½ … r₁₆ = 1 − δ,
//!    q̃ᵢ = Φ⁻¹(rᵢ), i = 9…16
//! 4. normalize by max |q̃| = Φ⁻¹(1 − δ) ≈ 1.8481
//!
//! The asymmetric halves guarantee 0 is a code value (paper footnote 2).
//!
//! §4 notes an ambiguity between this and "average of quantile values";
//! [`nf4_avg_quantiles`] implements that second reading (adjacent-pair
//! quantile averaging on a midpoint-offset grid, which preserves the −1/0/+1
//! structure). The two differ by < 0.01 per value — consistent with the
//! paper's "differs by less than 0.001" for its exact pair of formulas.

use crate::codes::code::Code;
use crate::numerics::special::phi_inv;

/// The NF4 offset δ = ½(1/32 + 1/30).
pub fn nf4_delta() -> f64 {
    0.5 * (1.0 / 32.0 + 1.0 / 30.0)
}

/// NF4 via quantiles of evenly spaced probabilities (implementation
/// variant — this is the canonical NF4 table).
pub fn nf4() -> Code {
    let delta = nf4_delta();
    let mut tilde = Vec::with_capacity(16);
    // negative half: p_1 = delta .. p_8 = 1/2 (8 points)
    for i in 0..8 {
        let p = delta + (0.5 - delta) * i as f64 / 7.0;
        tilde.push(phi_inv(p));
    }
    // positive half: r_9 .. r_16 over (1/2, 1-delta] (8 points; r_8 = 1/2
    // is the already-emitted zero)
    for i in 1..=8 {
        let r = 0.5 + (1.0 - delta - 0.5) * i as f64 / 8.0;
        tilde.push(phi_inv(r));
    }
    let maxabs = tilde.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    // Snap the structural values (−1, 0, +1) exactly: Φ⁻¹ is antisymmetric
    // only up to floating-point roundoff, and downstream invariants
    // (`has_endpoints_and_zero`) treat these as exact.
    let values: Vec<f64> = tilde.iter().map(|&q| snap(q / maxabs)).collect();
    Code::new("nf4", values)
}

/// Snap values within 1e-9 of −1, 0, +1 onto them exactly.
fn snap(v: f64) -> f64 {
    for target in [-1.0, 0.0, 1.0] {
        if (v - target).abs() < 1e-9 {
            return target;
        }
    }
    v
}

/// NF4 "average of quantile values" variant (§4's other reading): each code
/// value is the average of the quantiles at pᵢ ± s/2 where s is the grid
/// spacing of its half. Endpoint/zero structure is preserved by clamping the
/// outer probabilities to [δ, 1 − δ] and by the symmetry of the middle pair.
pub fn nf4_avg_quantiles() -> Code {
    let delta = nf4_delta();
    let mut tilde = Vec::with_capacity(16);
    // Midpoint-pair averaging: value i averages the quantiles at pᵢ ± s/2.
    // Only the outermost probabilities need clamping into (0, 1); the
    // middle pair straddles 1/2 symmetrically so the zero survives exactly.
    let s_neg = (0.5 - delta) / 7.0;
    for i in 0..8 {
        let p = delta + s_neg * i as f64;
        let lo = (p - s_neg / 2.0).max(delta / 4.0);
        let hi = p + s_neg / 2.0;
        tilde.push(0.5 * (phi_inv(lo) + phi_inv(hi)));
    }
    let s_pos = (1.0 - delta - 0.5) / 8.0;
    for i in 1..=8 {
        let r = 0.5 + s_pos * i as f64;
        let lo = r - s_pos / 2.0;
        let hi = (r + s_pos / 2.0).min(1.0 - delta / 4.0);
        tilde.push(0.5 * (phi_inv(lo) + phi_inv(hi)));
    }
    // Averaging shrinks the two extremes by different amounts, so each half
    // is normalized by its own extreme to restore the structural −1/0/+1
    // (the canonical variant has symmetric extremes, where this reduces to
    // the single max-abs normalization).
    let neg_max = tilde[0].abs();
    let pos_max = tilde[15].abs();
    let tilde: Vec<f64> = tilde
        .iter()
        .map(|&q| if q < 0.0 { q / neg_max * pos_max } else { q })
        .collect();
    let maxabs = tilde.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    // The middle value is exactly 0 only in the limit; snap values within
    // 5e-3 of 0 to 0 to preserve the code's structural invariant.
    let values: Vec<f64> = tilde
        .iter()
        .map(|&q| {
            let v = snap(q / maxabs);
            if v.abs() < 5e-3 {
                0.0
            } else {
                v
            }
        })
        .collect();
    Code::new("nf4-avgq", values)
}

/// The published NF4 table from bitsandbytes (float32 constants), for
/// cross-validation. Source: bitsandbytes `create_normal_map()` output as
/// cited in Dettmers et al. (2023).
pub const NF4_REFERENCE: [f64; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nf4_structure() {
        let c = nf4();
        assert_eq!(c.k(), 16);
        assert_eq!(c.values[0], -1.0);
        assert!((c.values[7] - 0.0).abs() < 1e-14, "q8 must be 0");
        assert_eq!(c.values[15], 1.0);
        assert!(c.has_endpoints_and_zero());
    }

    #[test]
    fn nf4_matches_published_table() {
        // bitsandbytes computes in float32 with scipy's ppf; agreement to
        // ~1.5e-3 absolute confirms the same construction.
        let c = nf4();
        for (got, want) in c.values.iter().zip(NF4_REFERENCE.iter()) {
            assert!(
                (got - want).abs() < 2.5e-3,
                "NF4 mismatch: got {got}, published {want}"
            );
        }
    }

    #[test]
    fn nf4_q2_matches_exact_formula() {
        // Exact check of one interior value against the construction math.
        let delta = nf4_delta();
        let p2 = delta + (0.5 - delta) / 7.0;
        let want = phi_inv(p2) / phi_inv(1.0 - delta).abs();
        let c = nf4();
        assert!((c.values[1] - (-want.abs())).abs() < 1e-12 || (c.values[1] - want).abs() < 1e-12);
    }

    #[test]
    fn nf4_asymmetric_spacing() {
        // The positive and negative halves use different grids, so the code
        // is NOT symmetric (except the pinned endpoints/zero).
        let c = nf4();
        let asym: f64 = (1..8).map(|i| (c.values[7 - i] + c.values[7 + i]).abs()).sum();
        assert!(asym > 0.01, "NF4 halves should differ: {asym}");
    }

    #[test]
    fn largest_tilde_value_is_paper_constant() {
        // Paper §2: Φ⁻¹(1−δ) ≈ 1.848.
        let v = phi_inv(1.0 - nf4_delta());
        assert!((v - 1.848).abs() < 1e-3);
    }

    #[test]
    fn avg_quantiles_variant_close_but_not_identical() {
        let a = nf4();
        let b = nf4_avg_quantiles();
        assert_eq!(b.k(), 16);
        assert!(b.has_endpoints_and_zero());
        let max_diff = a
            .values
            .iter()
            .zip(&b.values)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        // §4: the ambiguity is real but small. (The paper's exact formula
        // pair differs < 0.001; our midpoint-pair reading shifts the
        // clamped outermost values a bit more, ~0.035 worst case.)
        assert!(max_diff > 1e-6, "variants should differ");
        assert!(max_diff < 0.05, "variants should be close: {max_diff}");
    }

    #[test]
    fn nf4_monotone_gaps_away_from_zero() {
        // Quantile codes of a unimodal density have gaps growing with |x|.
        let c = nf4();
        let gaps: Vec<f64> = c.values.windows(2).map(|w| w[1] - w[0]).collect();
        for i in 8..gaps.len() - 1 {
            assert!(gaps[i + 1] > gaps[i], "positive-side gaps must grow");
        }
        for i in 1..7 {
            assert!(gaps[i - 1] > gaps[i], "negative-side gaps must shrink toward 0");
        }
    }
}
