//! Minimal host-side tensor type.
//!
//! The heavy math runs inside AOT-compiled XLA executables; the Rust side
//! only needs a row-major f32 matrix for weight storage, quantization, and
//! literal marshalling — so this is deliberately small instead of pulling a
//! full ndarray dependency into the vendor set.

use crate::util::rng::Rng;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    /// Matrix with iid N(0, sd²) entries.
    pub fn randn(rows: usize, cols: usize, sd: f32, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal() as f32 * sd;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column extracted into a new Vec (columns are strided in row-major).
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.at(r, c));
            }
        }
        t
    }

    /// Naive matmul — reference implementation for tests (the production
    /// path runs inside XLA).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for kk in 0..self.cols {
                let a = self.at(i, kk);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.at(kk, j);
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m.set(1, 2, 7.5);
        assert_eq!(m.at(1, 2), 7.5);
        assert_eq!(m.data[1 * 4 + 2], 7.5);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(3, 2), m.at(2, 3));
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut id = Matrix::zeros(4, 4);
        for i in 0..4 {
            id.set(i, i, 1.0);
        }
        let prod = m.matmul(&id);
        assert!(prod.max_abs_diff(&m) < 1e-6);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn row_col_access() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.col(2), vec![3.0, 6.0]);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.frobenius() - 5.0).abs() < 1e-6);
        assert!((m.mean_abs() - 3.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn bad_shape_panics() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }
}
