//! Tiny argument parser (the vendor set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Each binary declares its options up front so `--help` output
//! is generated consistently.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {s:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--blocks 64,256,1024`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer {t:?}"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of strings.
    pub fn str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
        }
    }
}

/// Command definition: name, description, options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        if !self.opts.is_empty() {
            let _ = writeln!(s, "\noptions:");
            for o in &self.opts {
                let d = o.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
                let kind = if o.is_flag { "" } else { " <value>" };
                let _ = writeln!(s, "  --{}{}\t{}{}", o.name, kind, o.help, d);
            }
        }
        s
    }

    /// Parse args for this command. Returns Err(usage) on `--help` or error.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    out.flags.insert(key, true);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} expects a value"))?
                        }
                    };
                    out.values.insert(key, val);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "testing")
            .opt("size", "a size", Some("64"))
            .opt("name", "a name", None)
            .flag("verbose", "noisy")
    }

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&v(&[])).unwrap();
        assert_eq!(a.usize("size", 0), 64);
        assert_eq!(a.get("name"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = cmd().parse(&v(&["--size", "128", "--name=x"])).unwrap();
        assert_eq!(a.usize("size", 0), 128);
        assert_eq!(a.get("name"), Some("x"));
    }

    #[test]
    fn flags_and_positional() {
        let a = cmd().parse(&v(&["pos1", "--verbose", "pos2"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&v(&["--bogus"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let e = cmd().parse(&v(&["--help"])).unwrap_err();
        assert!(e.contains("--size"));
        assert!(e.contains("testing"));
    }

    #[test]
    fn lists_parse() {
        let c = Command::new("t", "t").opt("blocks", "b", Some("64,256"));
        let a = c.parse(&v(&[])).unwrap();
        assert_eq!(a.usize_list("blocks", &[]), vec![64, 256]);
        let a = c.parse(&v(&["--blocks", "32, 64,4096"])).unwrap();
        assert_eq!(a.usize_list("blocks", &[]), vec![32, 64, 4096]);
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cmd().parse(&v(&["--verbose=1"])).is_err());
    }
}
