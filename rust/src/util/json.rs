//! Minimal JSON value type, parser, and pretty-printer.
//!
//! serde/serde_json are not in the vendor set, so AFQ carries a small,
//! spec-subset JSON implementation. It supports everything the framework
//! needs: the artifact manifest written by `python/compile/aot.py`, config
//! files, and experiment result output.
//!
//! Numbers are stored as f64 (like JavaScript); this is fine for manifests
//! and metrics. Unicode escapes `\uXXXX` are decoded including surrogate
//! pairs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_strs(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no NaN/Inf; encode as null (documented lossy case).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 from the byte stream.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3.25e2", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".to_string()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo wörld 日本\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld 日本");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
        assert!(Json::parse("nulll").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = Json::obj();
        o.set("xs", Json::from_f64s(&[1.0, 2.5]))
            .set("name", Json::Str("afq".into()))
            .set("ok", Json::Bool(true));
        let pretty = o.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), o);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(64.0).to_string_compact(), "64");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn nan_encodes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn object_helpers() {
        let mut o = Json::obj();
        o.set("n", Json::Num(3.0));
        assert_eq!(o.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(o.get("missing"), None);
    }
}
