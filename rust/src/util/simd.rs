//! Runtime-dispatched SIMD primitives for the quantize/decode/qgemm hot
//! loops — zero dependencies, `std::arch` only, with a scalar fallback
//! that is always compiled and always available.
//!
//! ## Dispatch
//!
//! The active [`SimdLevel`] is resolved once per process: the `AFQ_SIMD`
//! environment variable (`auto` | `off` | `scalar` | `sse4.1` | `avx2` |
//! `neon`) if set, else the best level the CPU supports
//! ([`detect_best`]: AVX2 → SSE4.1 → scalar on x86_64, NEON on aarch64,
//! scalar elsewhere). Requesting a level the runner cannot execute falls
//! back to [`detect_best`] with a warning — the override is a knob, not a
//! way to SIGILL. Tests and benches flip levels with [`set_level`]
//! (serialized via [`lock_for_tests`]); because every level is
//! bitwise-identical (below), a racing reader observing a stale level is
//! benign.
//!
//! The resolved level is wired into observability: the `afq_simd_level`
//! gauge (numeric [`SimdLevel::code`]), an `afq_simd_kernel_calls_total
//! {kernel=…,simd=…}` counter per dispatched kernel entry, and a
//! `simd_level` stamp in every bench envelope
//! ([`crate::util::bench::save_bench_doc`]).
//!
//! ## The determinism rule: vectorize across independent outputs, never
//! across a reduction
//!
//! Every vector path here must produce **bitwise** the scalar fallback's
//! output. f32 addition is not associative, so any reordering of a
//! reduction (a dot product's `acc += x[j]*v[j]` chain) changes bits —
//! lane-splitting a single accumulator into partial sums is therefore
//! forbidden, no matter how profitable. What *is* safe:
//!
//! - **Independent outputs.** [`axpy`] vectorizes over output elements
//!   (each gets exactly one `mul`+`add` per call) and [`dot4`] vectorizes
//!   across four *independent* accumulator chains — lane `i` is row `i`'s
//!   chain, fed in exactly the scalar `j` order via a 4×4 transpose. The
//!   reduction order per output never changes; only separate chains run
//!   in lockstep.
//! - **Exact order-free folds.** [`absmax_finite`] vectorizes a `max`
//!   fold: `max` over non-negative values rounds nothing, so it is
//!   associative/commutative in f32 and any fold order gives identical
//!   bits. [`encode_indices`] vectorizes a per-element classify
//!   (count of `x > bound` over the sorted boundary table — exact
//!   comparisons, no accumulation).
//! - **Never FMA.** Scalar Rust `a + b * c` rounds twice (Rust never
//!   contracts); a fused multiply-add rounds once. All vector paths use
//!   separate multiply and add intrinsics.
//!
//! A single-row dot product has no independent partner chains — it stays
//! scalar. The kernels in [`crate::quant::fused`] obey the same rule (the
//! Row-layout AXPY loop and the Col-layout MR=4 chains vectorize; the
//! remainder-row dot does not).

use crate::obs::registry::{counter, gauge, Counter};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// A dispatchable instruction-set level. `Scalar` is always available;
/// the vector levels exist only on their architecture and only when the
/// CPU reports the feature at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    Scalar,
    Sse41,
    Avx2,
    Neon,
}

impl SimdLevel {
    /// Canonical lowercase name (the `AFQ_SIMD` spelling, the counter
    /// label, and the `[level]` token baked into simd bench row names).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse41 => "sse4.1",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Stable numeric code for the `afq_simd_level` gauge (and the atomic
    /// dispatch slot): scalar 0, sse4.1 1, avx2 2, neon 3.
    pub fn code(self) -> u8 {
        match self {
            SimdLevel::Scalar => 0,
            SimdLevel::Sse41 => 1,
            SimdLevel::Avx2 => 2,
            SimdLevel::Neon => 3,
        }
    }

    fn from_code(c: u8) -> Option<SimdLevel> {
        match c {
            0 => Some(SimdLevel::Scalar),
            1 => Some(SimdLevel::Sse41),
            2 => Some(SimdLevel::Avx2),
            3 => Some(SimdLevel::Neon),
            _ => None,
        }
    }

    /// Parse an `AFQ_SIMD` value. `auto` (and empty) → `None` = detect;
    /// `off` is an alias for `scalar`; unknown strings → `None` is NOT
    /// returned (callers must warn) — they yield `Err(())` semantics via
    /// [`parse_env`].
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "scalar" | "none" => Some(SimdLevel::Scalar),
            "sse4.1" | "sse41" | "sse" => Some(SimdLevel::Sse41),
            "avx2" | "avx" => Some(SimdLevel::Avx2),
            "neon" => Some(SimdLevel::Neon),
            _ => None,
        }
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Whether this process can actually execute `level`'s instructions.
pub fn supported(level: SimdLevel) -> bool {
    match level {
        SimdLevel::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => std::arch::is_x86_feature_detected!("sse4.1"),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        // NEON is baseline on aarch64 — no runtime probe needed.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => true,
        #[allow(unreachable_patterns)]
        _ => false,
    }
}

/// Best level the runner supports: AVX2 → SSE4.1 → scalar on x86_64,
/// NEON on aarch64, scalar on everything else.
pub fn detect_best() -> SimdLevel {
    for l in [SimdLevel::Avx2, SimdLevel::Sse41, SimdLevel::Neon] {
        if supported(l) {
            return l;
        }
    }
    SimdLevel::Scalar
}

/// Every level this runner can execute, scalar first — the sweep the
/// forced-level parity batteries iterate.
pub fn available_levels() -> Vec<SimdLevel> {
    let mut out = vec![SimdLevel::Scalar];
    for l in [SimdLevel::Sse41, SimdLevel::Avx2, SimdLevel::Neon] {
        if supported(l) {
            out.push(l);
        }
    }
    out
}

/// Dispatch slot. `UNINIT` until the first [`level`] call resolves
/// `AFQ_SIMD`; after that it always holds a *supported* level's code.
static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);
const UNINIT: u8 = 0xFF;

fn level_gauge() -> &'static crate::obs::registry::Gauge {
    static G: OnceLock<crate::obs::registry::Gauge> = OnceLock::new();
    G.get_or_init(|| gauge("afq_simd_level"))
}

fn init_from_env() -> SimdLevel {
    let resolved = match std::env::var("AFQ_SIMD") {
        Ok(v) if !v.trim().is_empty() && v.trim().to_ascii_lowercase() != "auto" => {
            match SimdLevel::parse(&v) {
                Some(l) if supported(l) => l,
                Some(l) => {
                    let best = detect_best();
                    crate::log_warn!(
                        "AFQ_SIMD={} not supported on this CPU; using {}",
                        l.name(),
                        best.name()
                    );
                    best
                }
                None => {
                    let best = detect_best();
                    crate::log_warn!(
                        "unrecognized AFQ_SIMD={v:?} (want auto|off|scalar|sse4.1|avx2|neon); \
                         using {}",
                        best.name()
                    );
                    best
                }
            }
        }
        _ => detect_best(),
    };
    level_gauge().set(resolved.code() as i64);
    resolved
}

/// The active dispatch level (resolving `AFQ_SIMD` on first use). Kernels
/// read this once per invocation and pass it down, so one call never
/// mixes levels — not that it would matter: every level is bitwise-equal.
pub fn level() -> SimdLevel {
    match SimdLevel::from_code(LEVEL.load(Ordering::Relaxed)) {
        Some(l) => l,
        None => {
            let l = init_from_env();
            // A racing initializer may store first; both resolve the same
            // env+CPU, so last-writer-wins is deterministic.
            LEVEL.store(l.code(), Ordering::Relaxed);
            l
        }
    }
}

/// Force the dispatch level (tests, benches, CLI). Panics on a level this
/// runner cannot execute. Returns the previous level. Serialize
/// concurrent forcing with [`lock_for_tests`] — though a stale read is
/// harmless (all levels agree bitwise), an unsupported stale *write*
/// cannot happen because only supported levels are ever stored.
pub fn set_level(l: SimdLevel) -> SimdLevel {
    assert!(supported(l), "SIMD level {} not supported on this CPU", l.name());
    let prev = level();
    LEVEL.store(l.code(), Ordering::Relaxed);
    level_gauge().set(l.code() as i64);
    prev
}

static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Serializes tests that force dispatch levels (the level is
/// process-wide; `cargo test` runs in threads). Poisoning is ignored so
/// one failing forced-level test doesn't cascade.
pub fn lock_for_tests() -> MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Count one dispatched kernel entry under its level:
/// `afq_simd_kernel_calls_total{kernel=…,simd=…}`. Handles are cached —
/// the hot path pays one relaxed atomic add, no registry lock.
pub fn count_kernel_call(kernel: &'static str, l: SimdLevel) {
    fn build(kernel: &str) -> [Counter; 4] {
        let mk = |lv: SimdLevel| {
            counter(&format!(
                "afq_simd_kernel_calls_total{{kernel=\"{kernel}\",simd=\"{}\"}}",
                lv.name()
            ))
        };
        [mk(SimdLevel::Scalar), mk(SimdLevel::Sse41), mk(SimdLevel::Avx2), mk(SimdLevel::Neon)]
    }
    static QGEMM: OnceLock<[Counter; 4]> = OnceLock::new();
    static QUANTIZE: OnceLock<[Counter; 4]> = OnceLock::new();
    static OTHER: OnceLock<[Counter; 4]> = OnceLock::new();
    let cell = match kernel {
        "qgemm" => &QGEMM,
        "quantize" => &QUANTIZE,
        _ => &OTHER,
    };
    cell.get_or_init(|| build(kernel))[l.code() as usize].inc(1);
}

// ---------------------------------------------------------------------------
// axpy: out[j] += a * v[j] — the Row-layout inner loop. Outputs are
// independent (one mul+add each per call), so lane width is free.

/// `out[j] += a * v[j]` over `min(out.len(), v.len())` elements.
/// Bitwise-identical across levels: each element receives the same
/// single `mul` then `add` (never fused).
pub fn axpy(level: SimdLevel, out: &mut [f32], a: f32, v: &[f32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level` only holds values that passed `supported()`.
        SimdLevel::Avx2 => unsafe { axpy_avx2(out, a, v) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { axpy_sse(out, a, v) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { axpy_neon(out, a, v) },
        _ => axpy_scalar(out, a, v),
    }
}

#[inline]
fn axpy_scalar(out: &mut [f32], a: f32, v: &[f32]) {
    for (o, &x) in out.iter_mut().zip(v.iter()) {
        *o += a * x;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(out: &mut [f32], a: f32, v: &[f32]) {
    use std::arch::x86_64::*;
    let n = out.len().min(v.len());
    let va = _mm256_set1_ps(a);
    let mut j = 0usize;
    while j + 8 <= n {
        let x = _mm256_loadu_ps(v.as_ptr().add(j));
        let o = _mm256_loadu_ps(out.as_ptr().add(j));
        // mul then add, never fmadd: scalar `o += a*x` rounds twice.
        let r = _mm256_add_ps(o, _mm256_mul_ps(va, x));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), r);
        j += 8;
    }
    axpy_scalar(&mut out[j..n], a, &v[j..n]);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn axpy_sse(out: &mut [f32], a: f32, v: &[f32]) {
    use std::arch::x86_64::*;
    let n = out.len().min(v.len());
    let va = _mm_set1_ps(a);
    let mut j = 0usize;
    while j + 4 <= n {
        let x = _mm_loadu_ps(v.as_ptr().add(j));
        let o = _mm_loadu_ps(out.as_ptr().add(j));
        let r = _mm_add_ps(o, _mm_mul_ps(va, x));
        _mm_storeu_ps(out.as_mut_ptr().add(j), r);
        j += 4;
    }
    axpy_scalar(&mut out[j..n], a, &v[j..n]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(out: &mut [f32], a: f32, v: &[f32]) {
    use std::arch::aarch64::*;
    let n = out.len().min(v.len());
    let va = vdupq_n_f32(a);
    let mut j = 0usize;
    while j + 4 <= n {
        let x = vld1q_f32(v.as_ptr().add(j));
        let o = vld1q_f32(out.as_ptr().add(j));
        let r = vaddq_f32(o, vmulq_f32(va, x));
        vst1q_f32(out.as_mut_ptr().add(j), r);
        j += 4;
    }
    axpy_scalar(&mut out[j..n], a, &v[j..n]);
}

// ---------------------------------------------------------------------------
// dot4: four independent dot-product chains (the Col-layout MR=4 register
// block). Lane i is row i's chain; a 4×4 transpose feeds each lane in
// exactly ascending-j order, so every chain is bitwise the scalar chain.
// The j loop itself is NEVER lane-split — that would reorder a reduction.

/// Four dot products sharing `v`: returns
/// `[Σ x0[j]·v[j], Σ x1[j]·v[j], Σ x2[j]·v[j], Σ x3[j]·v[j]]`,
/// each accumulated in ascending `j` from a fresh 0.0 — bitwise the
/// scalar four-chain loop for every level.
pub fn dot4(
    level: SimdLevel,
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x3: &[f32],
    v: &[f32],
) -> [f32; 4] {
    debug_assert!(x0.len() >= v.len() && x1.len() >= v.len());
    debug_assert!(x2.len() >= v.len() && x3.len() >= v.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // AVX2 gains nothing here (the accumulator is 4 lanes wide by
        // construction); both x86 levels run the 128-bit transpose body.
        // SAFETY: `level` only holds values that passed `supported()`.
        SimdLevel::Avx2 | SimdLevel::Sse41 => unsafe { dot4_sse(x0, x1, x2, x3, v) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { dot4_neon(x0, x1, x2, x3, v) },
        _ => dot4_scalar(x0, x1, x2, x3, v),
    }
}

#[inline]
fn dot4_scalar(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], v: &[f32]) -> [f32; 4] {
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (j, &w) in v.iter().enumerate() {
        a0 += x0[j] * w;
        a1 += x1[j] * w;
        a2 += x2[j] * w;
        a3 += x3[j] * w;
    }
    [a0, a1, a2, a3]
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn dot4_sse(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], v: &[f32]) -> [f32; 4] {
    use std::arch::x86_64::*;
    let n = v.len();
    let mut acc = _mm_setzero_ps();
    let mut j = 0usize;
    while j + 4 <= n {
        let r0 = _mm_loadu_ps(x0.as_ptr().add(j));
        let r1 = _mm_loadu_ps(x1.as_ptr().add(j));
        let r2 = _mm_loadu_ps(x2.as_ptr().add(j));
        let r3 = _mm_loadu_ps(x3.as_ptr().add(j));
        // 4×4 transpose: cK = [x0[j+K], x1[j+K], x2[j+K], x3[j+K]].
        let t0 = _mm_unpacklo_ps(r0, r1);
        let t1 = _mm_unpackhi_ps(r0, r1);
        let t2 = _mm_unpacklo_ps(r2, r3);
        let t3 = _mm_unpackhi_ps(r2, r3);
        let c0 = _mm_movelh_ps(t0, t2);
        let c1 = _mm_movehl_ps(t2, t0);
        let c2 = _mm_movelh_ps(t1, t3);
        let c3 = _mm_movehl_ps(t3, t1);
        // One mul+add per j, in ascending j — the reduction order of each
        // lane's chain is exactly the scalar chain's.
        acc = _mm_add_ps(acc, _mm_mul_ps(c0, _mm_set1_ps(*v.get_unchecked(j))));
        acc = _mm_add_ps(acc, _mm_mul_ps(c1, _mm_set1_ps(*v.get_unchecked(j + 1))));
        acc = _mm_add_ps(acc, _mm_mul_ps(c2, _mm_set1_ps(*v.get_unchecked(j + 2))));
        acc = _mm_add_ps(acc, _mm_mul_ps(c3, _mm_set1_ps(*v.get_unchecked(j + 3))));
        j += 4;
    }
    let mut out = [0.0f32; 4];
    _mm_storeu_ps(out.as_mut_ptr(), acc);
    // Tail continues each lane's chain in j order.
    for jj in j..n {
        let w = v[jj];
        out[0] += x0[jj] * w;
        out[1] += x1[jj] * w;
        out[2] += x2[jj] * w;
        out[3] += x3[jj] * w;
    }
    out
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot4_neon(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], v: &[f32]) -> [f32; 4] {
    use std::arch::aarch64::*;
    let n = v.len();
    let mut acc = vdupq_n_f32(0.0);
    let mut j = 0usize;
    while j + 4 <= n {
        let r0 = vld1q_f32(x0.as_ptr().add(j));
        let r1 = vld1q_f32(x1.as_ptr().add(j));
        let r2 = vld1q_f32(x2.as_ptr().add(j));
        let r3 = vld1q_f32(x3.as_ptr().add(j));
        // 4×4 transpose via trn1/trn2 on f32 then f64 lanes.
        let t0 = vtrn1q_f32(r0, r1); // [x0[j],   x1[j],   x0[j+2], x1[j+2]]
        let t1 = vtrn2q_f32(r0, r1); // [x0[j+1], x1[j+1], x0[j+3], x1[j+3]]
        let t2 = vtrn1q_f32(r2, r3);
        let t3 = vtrn2q_f32(r2, r3);
        let c0 = vreinterpretq_f32_f64(vtrn1q_f64(
            vreinterpretq_f64_f32(t0),
            vreinterpretq_f64_f32(t2),
        )); // [x0[j], x1[j], x2[j], x3[j]]
        let c1 = vreinterpretq_f32_f64(vtrn1q_f64(
            vreinterpretq_f64_f32(t1),
            vreinterpretq_f64_f32(t3),
        ));
        let c2 = vreinterpretq_f32_f64(vtrn2q_f64(
            vreinterpretq_f64_f32(t0),
            vreinterpretq_f64_f32(t2),
        ));
        let c3 = vreinterpretq_f32_f64(vtrn2q_f64(
            vreinterpretq_f64_f32(t1),
            vreinterpretq_f64_f32(t3),
        ));
        acc = vaddq_f32(acc, vmulq_f32(c0, vdupq_n_f32(*v.get_unchecked(j))));
        acc = vaddq_f32(acc, vmulq_f32(c1, vdupq_n_f32(*v.get_unchecked(j + 1))));
        acc = vaddq_f32(acc, vmulq_f32(c2, vdupq_n_f32(*v.get_unchecked(j + 2))));
        acc = vaddq_f32(acc, vmulq_f32(c3, vdupq_n_f32(*v.get_unchecked(j + 3))));
        j += 4;
    }
    let mut out = [0.0f32; 4];
    vst1q_f32(out.as_mut_ptr(), acc);
    for jj in j..n {
        let w = v[jj];
        out[0] += x0[jj] * w;
        out[1] += x1[jj] * w;
        out[2] += x2[jj] * w;
        out[3] += x3[jj] * w;
    }
    out
}

// ---------------------------------------------------------------------------
// absmax_finite: the quantizer's saturating absmax fold. `max` over
// non-negative f32 rounds nothing, so the fold is order-free and the
// vector version is exact; non-finite lanes are masked to 0.0, matching
// the scalar fold's skip.

/// `fold(0.0, |a, v| if v.is_finite() { a.max(v.abs()) } else { a })` —
/// the blockwise absmax with the saturating non-finite contract.
/// Bitwise-identical across levels (exact fold).
pub fn absmax_finite(level: SimdLevel, blk: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level` only holds values that passed `supported()`.
        SimdLevel::Avx2 => unsafe { absmax_avx2(blk) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { absmax_sse(blk) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { absmax_neon(blk) },
        _ => absmax_scalar(blk),
    }
}

#[inline]
fn absmax_scalar(blk: &[f32]) -> f32 {
    blk.iter().fold(0.0f32, |a, &v| if v.is_finite() { a.max(v.abs()) } else { a })
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn absmax_avx2(blk: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = blk.len();
    let abs_mask = _mm256_set1_ps(f32::from_bits(0x7FFF_FFFF));
    let inf = _mm256_set1_ps(f32::INFINITY);
    let mut acc = _mm256_setzero_ps();
    let mut j = 0usize;
    while j + 8 <= n {
        let x = _mm256_loadu_ps(blk.as_ptr().add(j));
        let ax = _mm256_and_ps(x, abs_mask);
        // |x| < inf is false for both inf and NaN → those lanes mask to 0.
        let fin = _mm256_cmp_ps::<_CMP_LT_OQ>(ax, inf);
        acc = _mm256_max_ps(acc, _mm256_and_ps(ax, fin));
        j += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut m = lanes.iter().fold(0.0f32, |a, &v| a.max(v));
    for &v in &blk[j..] {
        if v.is_finite() {
            m = m.max(v.abs());
        }
    }
    m
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn absmax_sse(blk: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = blk.len();
    let abs_mask = _mm_set1_ps(f32::from_bits(0x7FFF_FFFF));
    let inf = _mm_set1_ps(f32::INFINITY);
    let mut acc = _mm_setzero_ps();
    let mut j = 0usize;
    while j + 4 <= n {
        let x = _mm_loadu_ps(blk.as_ptr().add(j));
        let ax = _mm_and_ps(x, abs_mask);
        let fin = _mm_cmplt_ps(ax, inf);
        acc = _mm_max_ps(acc, _mm_and_ps(ax, fin));
        j += 4;
    }
    let mut lanes = [0.0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut m = lanes.iter().fold(0.0f32, |a, &v| a.max(v));
    for &v in &blk[j..] {
        if v.is_finite() {
            m = m.max(v.abs());
        }
    }
    m
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn absmax_neon(blk: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = blk.len();
    let inf = vdupq_n_f32(f32::INFINITY);
    let mut acc = vdupq_n_f32(0.0);
    let mut j = 0usize;
    while j + 4 <= n {
        let x = vld1q_f32(blk.as_ptr().add(j));
        let ax = vabsq_f32(x);
        let fin = vcltq_f32(ax, inf);
        let masked = vreinterpretq_f32_u32(vandq_u32(vreinterpretq_u32_f32(ax), fin));
        acc = vmaxq_f32(acc, masked);
        j += 4;
    }
    let mut lanes = [0.0f32; 4];
    vst1q_f32(lanes.as_mut_ptr(), acc);
    let mut m = lanes.iter().fold(0.0f32, |a, &v| a.max(v));
    for &v in &blk[j..] {
        if v.is_finite() {
            m = m.max(v.abs());
        }
    }
    m
}

// ---------------------------------------------------------------------------
// encode_indices: the quantizer's per-element nearest-code classify.
// `encode_f32`'s branchless tree over 15 sorted boundaries is exactly
// "count of bounds with x > bound" (binary search ≡ rank), and the linear
// fallback for other widths IS that count — so the vector form
// accumulates 15 exact compares per lane. Non-finite inputs take the
// saturating contract (scalar fixup per affected chunk; the fast path
// detects all-finite chunks with one extra compare + movemask).

/// Encode one block: `out[i]` = code index of `blk[i]` under the
/// saturating non-finite contract (`finite → rank of blk[i]*inv in
/// bounds`, `NaN → zero_idx`, `+inf → top_idx`, `-inf → 0`). Bitwise the
/// scalar quantizer loop for every level.
pub fn encode_indices(
    level: SimdLevel,
    bounds: &[f32],
    blk: &[f32],
    inv: f32,
    zero_idx: u8,
    top_idx: u8,
    out: &mut [u8],
) {
    debug_assert_eq!(blk.len(), out.len());
    debug_assert!(bounds.len() < 256);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level` only holds values that passed `supported()`.
        SimdLevel::Avx2 => unsafe {
            encode_avx2(bounds, blk, inv, zero_idx, top_idx, out)
        },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe {
            encode_sse(bounds, blk, inv, zero_idx, top_idx, out)
        },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe {
            encode_neon(bounds, blk, inv, zero_idx, top_idx, out)
        },
        _ => encode_scalar(bounds, blk, inv, zero_idx, top_idx, out),
    }
}

#[inline]
fn encode_scalar(
    bounds: &[f32],
    blk: &[f32],
    inv: f32,
    zero_idx: u8,
    top_idx: u8,
    out: &mut [u8],
) {
    for (o, &v) in out.iter_mut().zip(blk.iter()) {
        *o = encode_one(bounds, v, inv, zero_idx, top_idx);
    }
}

/// The per-element contract, shared by the scalar path and every vector
/// path's tail/fixup — verbatim the quantizer's original branch ladder.
#[inline]
fn encode_one(bounds: &[f32], v: f32, inv: f32, zero_idx: u8, top_idx: u8) -> u8 {
    if v.is_finite() {
        crate::quant::encode_f32(bounds, v * inv)
    } else if v.is_nan() {
        zero_idx
    } else if v > 0.0 {
        top_idx
    } else {
        0
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn encode_avx2(
    bounds: &[f32],
    blk: &[f32],
    inv: f32,
    zero_idx: u8,
    top_idx: u8,
    out: &mut [u8],
) {
    use std::arch::x86_64::*;
    let n = blk.len();
    let vinv = _mm256_set1_ps(inv);
    let abs_mask = _mm256_set1_ps(f32::from_bits(0x7FFF_FFFF));
    let inf = _mm256_set1_ps(f32::INFINITY);
    let mut j = 0usize;
    while j + 8 <= n {
        let x = _mm256_loadu_ps(blk.as_ptr().add(j));
        let p = _mm256_mul_ps(x, vinv);
        let mut cnt = _mm256_setzero_si256();
        for &b in bounds {
            // `p > b` exactly as the scalar rank count (NaN lanes: false).
            let gt = _mm256_cmp_ps::<_CMP_GT_OQ>(p, _mm256_set1_ps(b));
            // all-ones is -1: subtracting adds 1 to matching lanes.
            cnt = _mm256_sub_epi32(cnt, _mm256_castps_si256(gt));
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, cnt);
        for (l, &c) in lanes.iter().enumerate() {
            out[j + l] = c as u8;
        }
        // Non-finite inputs need the saturating fixup; one compare +
        // movemask skips it for all-finite chunks.
        let fin = _mm256_cmp_ps::<_CMP_LT_OQ>(_mm256_and_ps(x, abs_mask), inf);
        if _mm256_movemask_ps(fin) != 0xFF {
            for l in 0..8 {
                let v = blk[j + l];
                if !v.is_finite() {
                    out[j + l] = encode_one(bounds, v, inv, zero_idx, top_idx);
                }
            }
        }
        j += 8;
    }
    encode_scalar(bounds, &blk[j..], inv, zero_idx, top_idx, &mut out[j..]);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn encode_sse(
    bounds: &[f32],
    blk: &[f32],
    inv: f32,
    zero_idx: u8,
    top_idx: u8,
    out: &mut [u8],
) {
    use std::arch::x86_64::*;
    let n = blk.len();
    let vinv = _mm_set1_ps(inv);
    let abs_mask = _mm_set1_ps(f32::from_bits(0x7FFF_FFFF));
    let inf = _mm_set1_ps(f32::INFINITY);
    let mut j = 0usize;
    while j + 4 <= n {
        let x = _mm_loadu_ps(blk.as_ptr().add(j));
        let p = _mm_mul_ps(x, vinv);
        let mut cnt = _mm_setzero_si128();
        for &b in bounds {
            let gt = _mm_cmpgt_ps(p, _mm_set1_ps(b));
            cnt = _mm_sub_epi32(cnt, _mm_castps_si128(gt));
        }
        let mut lanes = [0i32; 4];
        _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, cnt);
        for (l, &c) in lanes.iter().enumerate() {
            out[j + l] = c as u8;
        }
        let fin = _mm_cmplt_ps(_mm_and_ps(x, abs_mask), inf);
        if _mm_movemask_ps(fin) != 0xF {
            for l in 0..4 {
                let v = blk[j + l];
                if !v.is_finite() {
                    out[j + l] = encode_one(bounds, v, inv, zero_idx, top_idx);
                }
            }
        }
        j += 4;
    }
    encode_scalar(bounds, &blk[j..], inv, zero_idx, top_idx, &mut out[j..]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn encode_neon(
    bounds: &[f32],
    blk: &[f32],
    inv: f32,
    zero_idx: u8,
    top_idx: u8,
    out: &mut [u8],
) {
    use std::arch::aarch64::*;
    let n = blk.len();
    let vinv = vdupq_n_f32(inv);
    let inf = vdupq_n_f32(f32::INFINITY);
    let mut j = 0usize;
    while j + 4 <= n {
        let x = vld1q_f32(blk.as_ptr().add(j));
        let p = vmulq_f32(x, vinv);
        let mut cnt = vdupq_n_s32(0);
        for &b in bounds {
            let gt = vcgtq_f32(p, vdupq_n_f32(b));
            cnt = vsubq_s32(cnt, vreinterpretq_s32_u32(gt));
        }
        let mut lanes = [0i32; 4];
        vst1q_s32(lanes.as_mut_ptr(), cnt);
        for (l, &c) in lanes.iter().enumerate() {
            out[j + l] = c as u8;
        }
        let fin = vcltq_f32(vabsq_f32(x), inf);
        if vminvq_u32(fin) != u32::MAX {
            for l in 0..4 {
                let v = blk[j + l];
                if !v.is_finite() {
                    out[j + l] = encode_one(bounds, v, inv, zero_idx, top_idx);
                }
            }
        }
        j += 4;
    }
    encode_scalar(bounds, &blk[j..], inv, zero_idx, top_idx, &mut out[j..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn parse_levels() {
        assert_eq!(SimdLevel::parse("off"), Some(SimdLevel::Scalar));
        assert_eq!(SimdLevel::parse("scalar"), Some(SimdLevel::Scalar));
        assert_eq!(SimdLevel::parse("SSE4.1"), Some(SimdLevel::Sse41));
        assert_eq!(SimdLevel::parse("sse41"), Some(SimdLevel::Sse41));
        assert_eq!(SimdLevel::parse(" avx2 "), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse("neon"), Some(SimdLevel::Neon));
        assert_eq!(SimdLevel::parse("auto"), None);
        assert_eq!(SimdLevel::parse("avx512"), None);
        for l in [SimdLevel::Scalar, SimdLevel::Sse41, SimdLevel::Avx2, SimdLevel::Neon] {
            assert_eq!(SimdLevel::parse(l.name()), Some(l), "name round-trips");
            assert_eq!(SimdLevel::from_code(l.code()), Some(l), "code round-trips");
        }
    }

    #[test]
    fn detection_is_coherent() {
        let best = detect_best();
        assert!(supported(best));
        let avail = available_levels();
        assert_eq!(avail[0], SimdLevel::Scalar);
        assert!(avail.contains(&best));
        assert!(avail.iter().all(|&l| supported(l)));
    }

    #[test]
    fn set_level_round_trips_and_sets_gauge() {
        let _g = lock_for_tests();
        let initial = level(); // also forces env init
        for l in available_levels() {
            set_level(l);
            assert_eq!(level(), l);
            assert_eq!(level_gauge().get(), l.code() as i64);
        }
        set_level(initial);
    }

    /// Every available vector level matches the scalar primitives bitwise
    /// on random data — odd lengths for tails, non-finites for the masks.
    #[test]
    fn prop_primitives_bitwise_match_scalar() {
        let levels = available_levels();
        prop::check(64, |g| {
            let n = g.usize_in(0, 70);
            let mut v = g.vec_normal_f32(n);
            let x0 = g.vec_normal_f32(n);
            let x1 = g.vec_normal_f32(n);
            let x2 = g.vec_normal_f32(n);
            let x3 = g.vec_normal_f32(n);
            let base = g.vec_normal_f32(n);
            let a = g.f32_in(-2.0, 2.0);
            for w in v.iter_mut() {
                if g.bool(0.1) {
                    *w = *g.pick(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
                }
            }
            let want_max = absmax_scalar(&v);
            let want_dot = dot4_scalar(&x0, &x1, &x2, &x3, &base);
            let mut want_axpy = base.clone();
            axpy_scalar(&mut want_axpy, a, &x0);
            for &l in &levels {
                if absmax_finite(l, &v).to_bits() != want_max.to_bits() {
                    return Err(format!("absmax diverged at level {l} n={n}"));
                }
                let d = dot4(l, &x0, &x1, &x2, &x3, &base);
                if bits(&d) != bits(&want_dot) {
                    return Err(format!("dot4 diverged at level {l} n={n}"));
                }
                let mut got = base.clone();
                axpy(l, &mut got, a, &x0);
                if bits(&got) != bits(&want_axpy) {
                    return Err(format!("axpy diverged at level {l} n={n}"));
                }
            }
            Ok(())
        });
    }

    /// encode_indices: vector rank-count == scalar `encode_f32` tree, and
    /// the saturating non-finite contract survives every level — NaN,
    /// ±inf, and inv == 0 (all-non-finite block) included.
    #[test]
    fn prop_encode_indices_bitwise_match_scalar() {
        let code = crate::codes::nf4();
        let bounds: Vec<f32> = code.boundaries().iter().map(|&b| b as f32).collect();
        let levels = available_levels();
        prop::check(64, |g| {
            let n = g.usize_in(0, 70);
            let mut blk = g.vec_normal_f32(n);
            for v in blk.iter_mut() {
                if g.bool(0.15) {
                    *v = *g.pick(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
                }
            }
            let inv = *g.pick(&[0.0f32, 0.37, 1.0, 4.5]);
            let mut want = vec![0u8; n];
            encode_scalar(&bounds, &blk, inv, 7, 15, &mut want);
            for &l in &levels {
                let mut got = vec![0u8; n];
                encode_indices(l, &bounds, &blk, inv, 7, 15, &mut got);
                if got != want {
                    return Err(format!("encode diverged at level {l} n={n} inv={inv}"));
                }
            }
            Ok(())
        });
    }

    /// Non-15-bound tables (the linear-scan encode path) vectorize to the
    /// same rank count too.
    #[test]
    fn encode_indices_non_nf4_width() {
        let bounds = vec![-0.5f32, 0.0, 0.5]; // 4-entry code
        let mut rng = Rng::new(42);
        let blk: Vec<f32> = (0..37).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0u8; blk.len()];
        encode_scalar(&bounds, &blk, 1.0, 1, 3, &mut want);
        for l in available_levels() {
            let mut got = vec![0u8; blk.len()];
            encode_indices(l, &bounds, &blk, 1.0, 1, 3, &mut got);
            assert_eq!(got, want, "level {l}");
        }
    }

    #[test]
    fn kernel_call_counters_register() {
        let _g = lock_for_tests();
        count_kernel_call("qgemm", SimdLevel::Scalar);
        count_kernel_call("quantize", SimdLevel::Scalar);
        let c = counter("afq_simd_kernel_calls_total{kernel=\"qgemm\",simd=\"scalar\"}");
        assert!(c.get() >= 1);
    }
}
