//! Infrastructure substrates: RNG, JSON, CLI parsing, benchmarking,
//! property testing, and a thread pool.
//!
//! These exist because the offline vendor set lacks `rand`, `serde_json`,
//! `clap`, `criterion`, `proptest`, and `tokio`; each submodule is a small,
//! fully-tested replacement scoped to what AFQ needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod threadpool;

use std::time::Instant;

/// Wall-clock timer with human-readable display.
pub struct Timer {
    start: Instant,
    label: String,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Self { start: Instant::now(), label: label.to_string() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!("[{}] {:.3}s", self.label, self.elapsed_s())
    }
}

/// Write a string to a file, creating parent directories.
pub fn write_file(path: &str, contents: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)
}

/// True when `AFQ_REQUIRE_ARTIFACTS=1`: artifact-gated tests that normally
/// skip (with a message) when the AOT artifacts are absent must **fail**
/// instead. Set this in any CI job that runs `make artifacts` first, so a
/// broken artifact build cannot silently turn the integration suite into
/// a no-op.
pub fn artifacts_required() -> bool {
    std::env::var("AFQ_REQUIRE_ARTIFACTS").map(|v| v == "1").unwrap_or(false)
}

/// Resolve an artifacts directory to wherever its `manifest.json`
/// actually is: `dir` as given, or — when `dir` is relative and empty —
/// one level up (`../dir`). The single owner of the cwd quirk that
/// `make artifacts` writes to the repo root while cargo runs test/bench
/// binaries with cwd = the package root (`rust/`), so every caller can
/// keep saying `"artifacts"` and work from either directory.
/// [`crate::runtime::Manifest::load`] resolves through this too.
pub fn resolve_artifacts_dir(dir: &str) -> Option<String> {
    if std::path::Path::new(dir).join("manifest.json").exists() {
        return Some(dir.to_string());
    }
    if std::path::Path::new(dir).is_relative() {
        let up = format!("../{dir}");
        if std::path::Path::new(&up).join("manifest.json").exists() {
            return Some(up);
        }
    }
    None
}

/// Single artifact-gate for tests: true when the AOT artifacts exist at
/// `dir` (resolved via [`resolve_artifacts_dir`]). When absent, panics
/// under [`artifacts_required`] (CI mode), otherwise logs the skip and
/// returns false — so every artifact-gated test reduces to
/// `if !artifacts_available("artifacts") { return; }`.
pub fn artifacts_available(dir: &str) -> bool {
    if resolve_artifacts_dir(dir).is_some() {
        return true;
    }
    assert!(
        !artifacts_required(),
        "AFQ_REQUIRE_ARTIFACTS=1 but {dir}/manifest.json is missing — run `make artifacts`"
    );
    // CI can never hit this branch silently: artifact jobs set
    // AFQ_REQUIRE_ARTIFACTS=1, which panics above instead of skipping.
    crate::log_warn!("skipping: no artifacts at {dir}/ (run `make artifacts`)");
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_time() {
        let t = Timer::start("x");
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(t.elapsed_s() >= 0.009);
        assert!(t.report().contains("[x]"));
    }

    #[test]
    fn write_file_creates_dirs() {
        let dir = std::env::temp_dir().join("afq_util_test");
        let path = dir.join("a/b/c.txt");
        let p = path.to_str().unwrap();
        write_file(p, "hello").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "hello");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
