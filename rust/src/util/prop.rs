//! Property-based testing driver (the vendor set has no `proptest`).
//!
//! A minimal shrinking property tester: generate random cases from a seeded
//! [`crate::util::rng::Rng`], run the property, and on failure greedily
//! shrink the failing case toward "smaller" values before reporting.
//!
//! Usage:
//! ```ignore
//! prop::check(256, |g| {
//!     let n = g.usize_in(1, 4096);
//!     let xs = g.vec_f32(n, -4.0, 4.0);
//!     // ... assert invariant, or return Err(msg)
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to properties. Records the scalar choices made so
/// the driver can replay/shrink them.
pub struct Gen {
    rng: Rng,
    /// When replaying a shrunk trace, choices come from here instead.
    replay: Option<Vec<f64>>,
    cursor: usize,
    pub trace: Vec<f64>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), replay: None, cursor: 0, trace: Vec::new() }
    }

    fn from_trace(trace: Vec<f64>) -> Self {
        Self { rng: Rng::new(0), replay: Some(trace), cursor: 0, trace: Vec::new() }
    }

    fn choice(&mut self, fresh: f64) -> f64 {
        let v = match &self.replay {
            Some(t) => t.get(self.cursor).copied().unwrap_or(fresh),
            None => fresh,
        };
        self.cursor += 1;
        self.trace.push(v);
        v
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let fresh = lo as f64 + self.rng.f64() * (hi - lo + 1) as f64;
        let v = self.choice(fresh.floor());
        (v as usize).clamp(lo, hi)
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let fresh = self.rng.range_f64(lo, hi);
        self.choice(fresh).clamp(lo, hi)
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    /// bool with probability p of true.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64_in(0.0, 1.0) < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Vector of uniform f32s. (Each element is one recorded choice, so
    /// shrinking can zero them individually.)
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vector of standard normal f32s.
    pub fn vec_normal_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let fresh = self.rng.normal();
                self.choice(fresh) as f32
            })
            .collect()
    }
}

/// Outcome of a single property run.
pub type PropResult = Result<(), String>;

fn run_trace<P: Fn(&mut Gen) -> PropResult>(prop: &P, trace: Vec<f64>) -> (PropResult, Vec<f64>) {
    let mut g = Gen::from_trace(trace);
    let r = prop(&mut g);
    let t = std::mem::take(&mut g.trace);
    (r, t)
}

/// Run `cases` random cases of `prop`; panic with the (shrunk) failing trace
/// on failure. The base seed is fixed for reproducibility and can be
/// overridden with AFQ_PROP_SEED.
pub fn check<P: Fn(&mut Gen) -> PropResult>(cases: usize, prop: P) {
    let base_seed = std::env::var("AFQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xAFC0_FFEE_u64);
    for case in 0..cases {
        let mut g = Gen::new(base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9));
        let result = prop(&mut g);
        if let Err(msg) = result {
            let trace = g.trace.clone();
            let (shrunk_trace, shrunk_msg) = shrink(&prop, trace, msg);
            panic!(
                "property failed (case {case}, seed base {base_seed}):\n  {shrunk_msg}\n  shrunk trace ({} choices): {:?}",
                shrunk_trace.len(),
                &shrunk_trace[..shrunk_trace.len().min(32)]
            );
        }
    }
}

/// Greedy shrink: try zeroing / halving / truncating choices while the
/// property still fails. Bounded effort.
fn shrink<P: Fn(&mut Gen) -> PropResult>(
    prop: &P,
    mut trace: Vec<f64>,
    mut msg: String,
) -> (Vec<f64>, String) {
    let mut budget = 2000usize;
    let mut progress = true;
    while progress && budget > 0 {
        progress = false;
        // Try halving each nonzero choice.
        for i in 0..trace.len() {
            if budget == 0 {
                break;
            }
            let orig = trace[i];
            for candidate in [0.0, orig / 2.0, orig.trunc()] {
                if candidate == orig {
                    continue;
                }
                budget -= 1;
                let mut t = trace.clone();
                t[i] = candidate;
                let (r, actual) = run_trace(prop, t);
                if let Err(m) = r {
                    trace = actual;
                    msg = m;
                    progress = true;
                    break;
                }
                if budget == 0 {
                    break;
                }
            }
        }
    }
    (trace, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |g| {
            let a = g.f64_in(-10.0, 10.0);
            if (a + 0.0 - a).abs() < 1e-12 {
                Ok(())
            } else {
                Err("addition identity failed".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(64, |g| {
            let a = g.f64_in(0.0, 100.0);
            if a < 120.0 && a > 90.0 {
                Err(format!("hit the bad region: {a}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check(128, |g| {
            let n = g.usize_in(3, 17);
            if !(3..=17).contains(&n) {
                return Err(format!("usize_in out of bounds: {n}"));
            }
            let x = g.f32_in(-1.0, 1.0);
            if !(-1.0..=1.0).contains(&x) {
                return Err(format!("f32_in out of bounds: {x}"));
            }
            let v = g.vec_f32(n, 0.0, 2.0);
            if v.len() != n || v.iter().any(|&e| !(0.0..=2.0).contains(&e)) {
                return Err("vec_f32 wrong".into());
            }
            Ok(())
        });
    }

    #[test]
    fn shrinker_reduces_magnitude() {
        // Fails whenever first choice >= 10; shrinker should land near 10.
        let prop = |g: &mut Gen| {
            let a = g.f64_in(0.0, 1000.0);
            if a >= 10.0 {
                Err(format!("a={a}"))
            } else {
                Ok(())
            }
        };
        // find a failure manually, then shrink
        let mut g = Gen::new(12345);
        let mut tries = 0;
        let trace = loop {
            g = Gen::new(12345 + tries);
            if prop(&mut g).is_err() {
                break g.trace.clone();
            }
            tries += 1;
        };
        let (shrunk, _) = shrink(&prop, trace, "seed".into());
        // Shrunk first choice should still fail but be much smaller than 1000.
        assert!(shrunk[0] < 600.0, "shrunk to {shrunk:?}");
        let (r, _) = run_trace(&prop, shrunk.clone());
        assert!(r.is_err());
    }
}
