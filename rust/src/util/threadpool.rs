//! Small fixed-size thread pool (no tokio in the vendor set; CPU-bound work
//! doesn't want an async runtime anyway).
//!
//! Two parallel-map primitives, one per lifetime regime:
//!
//! - [`ThreadPool::map_indexed`] — runs on a persistent pool; closures must
//!   be `'static` (jobs cross a channel), so inputs get `Arc`'d.
//! - [`scope_map`] — free function on std scoped threads; closures may
//!   **borrow** from the caller. This is what the quantizer/fused-GEMM hot
//!   paths use ([`crate::quant::fused`]).
//!
//! ## Scheduling
//!
//! [`scope_map`] is a **work-stealing** scheduler: the index range `0..n`
//! is split into one contiguous arena per worker, each worker claims small
//! chunks from its own arena with a per-arena atomic cursor, and a worker
//! whose arena drains steals chunks from the other arenas (scanning from
//! its neighbour, wrapping). Owners and thieves use the same cursor, so
//! every index is claimed exactly once; chunked claims keep the common
//! case one atomic op per `CHUNK` items instead of one per item, while
//! stealing still balances uneven per-item costs (different block sizes,
//! ragged tail panels).
//!
//! ## Determinism contract
//!
//! Scheduling never touches results: `f` is called exactly once per index
//! and results are returned **in index order**, so any caller computing
//! independent per-index outputs gets a result *bit-identical* to the
//! serial `(0..n).map(f)` — regardless of worker count, arena layout,
//! chunk size, or which worker stole what. The fused quantizer paths rely
//! on this.
//!
//! ## Panic semantics
//!
//! A panic inside a job is never a hang and never silently shrinks the
//! pool:
//!
//! - [`scope_map`] and [`ThreadPool::map_indexed`] catch the panic at the
//!   item, stop handing out further work, and **re-raise the first panic
//!   payload on the calling thread** after the workers wind down.
//! - Fire-and-forget [`ThreadPool::execute`] jobs are unwound inside the
//!   worker loop; the worker stays alive for subsequent jobs.
//! - Every caught panic increments `afq_threadpool_panics_total` in the
//!   metrics registry.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Pool utilization counters in the global metrics registry, registered
/// once (OnceLock) so the hot paths never take the registry lock.
struct PoolMetrics {
    jobs: crate::obs::registry::Counter,
    items: crate::obs::registry::Counter,
    busy_us: crate::obs::registry::Counter,
    panics: crate::obs::registry::Counter,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: OnceLock<PoolMetrics> = OnceLock::new();
    M.get_or_init(|| PoolMetrics {
        jobs: crate::obs::registry::counter("afq_threadpool_jobs_total"),
        items: crate::obs::registry::counter("afq_threadpool_items_total"),
        busy_us: crate::obs::registry::counter("afq_threadpool_busy_us_total"),
        panics: crate::obs::registry::counter("afq_threadpool_panics_total"),
    })
}

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Pool with `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("afq-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // A panicking job must not take the worker
                                // with it: unwind here, count it, keep
                                // serving. (map_indexed catches at the item
                                // instead, to carry the payload back to
                                // its caller.)
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    pool_metrics().panics.inc(1);
                                    crate::log_warn!(
                                        "threadpool: job panicked; worker kept alive"
                                    );
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx, workers, size: n }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget. A panicking job is unwound inside the worker (the
    /// worker survives) and counted in `afq_threadpool_panics_total`.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        pool_metrics().jobs.inc(1);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Parallel map over 0..n: `f(i)` for each index, results in order.
    /// Blocks until all complete.
    ///
    /// If `f` panics for any index, the panic is caught at the item,
    /// remaining work is abandoned, and the **first** payload is re-raised
    /// on the calling thread — never a deadlock on the result channel, and
    /// the pool's workers all survive for the next call.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if n == 0 {
            return Vec::new();
        }
        pool_metrics().items.inc(n as u64);
        let f = Arc::new(f);
        type Slot<T> = (usize, Result<T, PanicPayload>);
        let (rtx, rrx): (Sender<Slot<T>>, Receiver<Slot<T>>) = channel();
        let next = Arc::new(AtomicUsize::new(0));
        // One task per worker; each pulls indices from the shared counter
        // (good load balance for uneven item costs like different block
        // sizes).
        let tasks = self.size.min(n);
        for _ in 0..tasks {
            let f = Arc::clone(&f);
            let next = Arc::clone(&next);
            let rtx = rtx.clone();
            self.execute(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // AssertUnwindSafe: on Err the payload is re-raised to the
                // caller before any result is observed, so torn state in
                // `f`'s captures is never read.
                let out = catch_unwind(AssertUnwindSafe(|| f(i)));
                let panicked = out.is_err();
                if panicked {
                    pool_metrics().panics.inc(1);
                }
                if rtx.send((i, out)).is_err() || panicked {
                    break;
                }
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            match rrx.recv() {
                Ok((i, Ok(v))) => slots[i] = Some(v),
                // First panic wins: dropping `rrx` makes the surviving
                // tasks' sends fail so they stop pulling work, then the
                // payload unwinds the caller.
                Ok((_, Err(payload))) => {
                    drop(rrx);
                    resume_unwind(payload);
                }
                // Unreachable while the pool holds its workers (each task
                // sends every result it produces before exiting), but a
                // clear message beats a unwrap if that ever changes.
                Err(_) => panic!("threadpool: result channel closed early"),
            }
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

/// Number of workers to use when the caller has no opinion: the machine's
/// available parallelism (1 if unknown).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One worker's contiguous slice of the index range: a cursor that both
/// the owner and thieves advance with the same `fetch_add`, so chunks are
/// handed out exactly once no matter who claims them.
struct Arena {
    next: AtomicUsize,
    end: usize,
}

/// How many indices a single cursor claim takes. Small enough that the
/// tail of an uneven workload still spreads across workers, large enough
/// that the per-item cost is amortized over several items.
const CHUNK: usize = 4;

/// Blocking parallel map over `0..n` with *borrowing* closures, scheduled
/// by **work stealing**: the range splits into one contiguous arena per
/// scoped worker; each worker claims `CHUNK`-sized runs from its own arena
/// and, when that drains, steals runs from the other arenas (scanning from
/// its neighbour, wrapping). Results come back `f(0), f(1), …` in index
/// order.
///
/// Determinism contract: `f` is called exactly once per index and results
/// are returned in index order, so any caller that computes independent
/// per-index outputs gets a result *bit-identical* to the serial
/// `(0..n).map(f)` — regardless of worker count, arena split, or steal
/// interleaving. The fused quantizer paths rely on this.
///
/// Panic semantics: a panic in `f` is caught at the item; all workers
/// stop claiming new chunks, the scope joins (never a hang), and the
/// first payload is re-raised on the calling thread.
///
/// `workers == 1` (or `n <= 1`) short-circuits to the serial loop on the
/// calling thread: no spawn overhead on the degenerate configurations.
pub fn scope_map<T, F>(workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    pool_metrics().items.inc(n as u64);
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    // Contiguous per-worker arenas (the last may be short or empty when n
    // doesn't divide evenly — stealing erases the imbalance).
    let per = n.div_ceil(workers);
    let arenas: Vec<Arena> = (0..workers)
        .map(|w| Arena { next: AtomicUsize::new((w * per).min(n)), end: ((w + 1) * per).min(n) })
        .collect();
    let poisoned = AtomicBool::new(false);
    let first_panic: Mutex<Option<PanicPayload>> = Mutex::new(None);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|wid| {
                let arenas = &arenas;
                let poisoned = &poisoned;
                let first_panic = &first_panic;
                let f = &f;
                s.spawn(move || {
                    // Worker utilization: one timer per worker per call, not
                    // per item — the per-index loop costs one atomic op per
                    // CHUNK items beyond the work itself.
                    let t0 = std::time::Instant::now();
                    let mut got: Vec<(usize, T)> = Vec::new();
                    // Own arena first, then steal round-robin from wid+1.
                    'arenas: for off in 0..workers {
                        let a = &arenas[(wid + off) % workers];
                        loop {
                            let lo = a.next.fetch_add(CHUNK, Ordering::Relaxed);
                            if lo >= a.end {
                                break; // drained (overshoot is harmless)
                            }
                            let hi = (lo + CHUNK).min(a.end);
                            for i in lo..hi {
                                if poisoned.load(Ordering::Relaxed) {
                                    break 'arenas;
                                }
                                match catch_unwind(AssertUnwindSafe(|| f(i))) {
                                    Ok(v) => got.push((i, v)),
                                    Err(payload) => {
                                        pool_metrics().panics.inc(1);
                                        poisoned.store(true, Ordering::Relaxed);
                                        if let Ok(mut slot) = first_panic.lock() {
                                            slot.get_or_insert(payload);
                                        }
                                        break 'arenas;
                                    }
                                }
                            }
                        }
                    }
                    let busy = t0.elapsed().as_micros() as u64;
                    (got, busy)
                })
            })
            .collect();
        let mut busy_total = 0u64;
        for h in handles {
            let (got, busy) = h.join().expect("scoped worker panicked");
            busy_total += busy;
            for (i, v) in got {
                slots[i] = Some(v);
            }
        }
        pool_metrics().busy_us.inc(busy_total);
    });
    if let Some(payload) = first_panic.lock().ok().and_then(|mut s| s.take()) {
        resume_unwind(payload);
    }
    slots.into_iter().map(|s| s.unwrap()).collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map_indexed(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map_indexed(0, |_| 1);
        assert!(out.is_empty());
    }

    #[test]
    fn map_with_uneven_work() {
        let pool = ThreadPool::new(4);
        let out = pool.map_indexed(16, |i| {
            // Uneven cost per item.
            let mut acc = 0u64;
            for k in 0..(i * 10_000) {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, (j, _)) in out.iter().enumerate() {
            assert_eq!(i, *j);
        }
    }

    /// Satellite regression: a panic-injecting job used to deadlock
    /// `map_indexed` forever (the panicking worker's result never arrived
    /// but `rrx.recv()` kept waiting). It must now propagate the panic to
    /// the caller — and leave the pool fully usable afterwards.
    #[test]
    fn map_indexed_propagates_job_panic_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let panics_before = pool_metrics().panics.get();
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.map_indexed(64, |i| {
                if i == 17 {
                    panic!("injected job panic");
                }
                i * 2
            })
        }));
        let payload = caught.expect_err("panic must propagate, not hang");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "injected job panic");
        assert!(pool_metrics().panics.get() > panics_before);
        // No silent worker loss: the same pool still completes a full map.
        let out = pool.map_indexed(32, |i| i + 1);
        assert_eq!(out, (1..=32).collect::<Vec<_>>());
    }

    /// Satellite regression: a panicking fire-and-forget job must not kill
    /// its worker — all later jobs still run on a size-1 pool, where a
    /// dead worker would stall everything.
    #[test]
    fn execute_survives_job_panic() {
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicU64::new(0));
        pool.execute(|| panic!("injected execute panic"));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn scope_map_matches_serial_for_any_worker_count() {
        let data: Vec<u64> = (0..97).map(|i| i * 3 + 1).collect();
        let serial: Vec<u64> = (0..data.len()).map(|i| data[i] * data[i]).collect();
        for workers in [1usize, 2, 3, 7, 16, 64] {
            // closure borrows `data` — the whole point of scope_map
            let out = scope_map(workers, data.len(), |i| data[i] * data[i]);
            assert_eq!(out, serial, "workers={workers}");
        }
    }

    /// The stealing path specifically: give worker 0's arena all the heavy
    /// items so the other workers must steal to finish, and check the
    /// result is still index-ordered and serial-identical.
    #[test]
    fn scope_map_steals_from_uneven_arenas() {
        let n = 64;
        let serial: Vec<u64> = (0..n as u64)
            .map(|i| {
                let spin = if i < 8 { 200_000 } else { 10 };
                (0..spin).fold(i, |a, k| a.wrapping_add(k))
            })
            .collect();
        for workers in [2usize, 4, 8, 32] {
            let out = scope_map(workers, n, |i| {
                let i = i as u64;
                let spin = if i < 8 { 200_000u64 } else { 10 };
                (0..spin).fold(i, |a, k| a.wrapping_add(k))
            });
            assert_eq!(out, serial, "workers={workers}");
        }
    }

    /// Satellite regression: a panic inside a scoped worker's item must
    /// re-raise on the caller (with the original payload), never hang the
    /// scope or poison later calls.
    #[test]
    fn scope_map_propagates_panic() {
        let panics_before = pool_metrics().panics.get();
        let caught = catch_unwind(AssertUnwindSafe(|| {
            scope_map(4, 100, |i| {
                if i == 63 {
                    panic!("injected scope panic");
                }
                i
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "injected scope panic");
        assert!(pool_metrics().panics.get() > panics_before);
        // Subsequent calls are unaffected.
        let out = scope_map(4, 10, |i| i);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty_and_single() {
        let out: Vec<u8> = scope_map(8, 0, |_| 1);
        assert!(out.is_empty());
        let out = scope_map(8, 1, |i| i + 10);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn default_workers_at_least_one() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn utilization_counters_advance() {
        let before = pool_metrics().items.get();
        let _ = scope_map(4, 32, |i| i);
        assert!(pool_metrics().items.get() >= before + 32);
        let jobs_before = pool_metrics().jobs.get();
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool);
        assert!(pool_metrics().jobs.get() >= jobs_before + 1);
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.map_indexed(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }
}
