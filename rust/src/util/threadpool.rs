//! Small fixed-size thread pool (no tokio in the vendor set; CPU-bound work
//! doesn't want an async runtime anyway).
//!
//! Two parallel-map primitives, one per lifetime regime:
//!
//! - [`ThreadPool::map_indexed`] — runs on a persistent pool; closures must
//!   be `'static` (jobs cross a channel), so inputs get `Arc`'d.
//! - [`scope_map`] — free function on std scoped threads; closures may
//!   **borrow** from the caller. This is what the quantizer/fused-GEMM hot
//!   paths use ([`crate::quant::fused`]): no `Arc`, no clones, and the
//!   same atomic work-stealing discipline.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Pool utilization counters in the global metrics registry, registered
/// once (OnceLock) so the hot paths never take the registry lock.
struct PoolMetrics {
    jobs: crate::obs::registry::Counter,
    items: crate::obs::registry::Counter,
    busy_us: crate::obs::registry::Counter,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: OnceLock<PoolMetrics> = OnceLock::new();
    M.get_or_init(|| PoolMetrics {
        jobs: crate::obs::registry::counter("afq_threadpool_jobs_total"),
        items: crate::obs::registry::counter("afq_threadpool_items_total"),
        busy_us: crate::obs::registry::counter("afq_threadpool_busy_us_total"),
    })
}

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Pool with `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("afq-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx, workers, size: n }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        pool_metrics().jobs.inc(1);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Parallel map over 0..n: `f(i)` for each index, results in order.
    /// Blocks until all complete. `f` must be cloneable across threads.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if n == 0 {
            return Vec::new();
        }
        pool_metrics().items.inc(n as u64);
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, T)>, Receiver<(usize, T)>) = channel();
        let next = Arc::new(AtomicUsize::new(0));
        // One task per worker; each pulls indices from the shared counter
        // (work stealing by atomic increment — good load balance for uneven
        // item costs like different block sizes).
        let tasks = self.size.min(n);
        for _ in 0..tasks {
            let f = Arc::clone(&f);
            let next = Arc::clone(&next);
            let rtx = rtx.clone();
            self.execute(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                if rtx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rrx.recv().expect("worker died");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

/// Number of workers to use when the caller has no opinion: the machine's
/// available parallelism (1 if unknown).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Blocking parallel map over `0..n` with *borrowing* closures: spawns up
/// to `workers` scoped threads that pull indices from a shared atomic
/// counter (work stealing by atomic increment, like
/// [`ThreadPool::map_indexed`]) and returns `f(0), f(1), …` in index order.
///
/// Determinism contract: `f` is called exactly once per index and results
/// are returned in index order, so any caller that computes independent
/// per-index outputs gets a result *bit-identical* to the serial
/// `(0..n).map(f)` — regardless of worker count or scheduling. The fused
/// quantizer paths rely on this.
///
/// `workers == 1` (or `n <= 1`) short-circuits to the serial loop on the
/// calling thread: no spawn overhead on the degenerate configurations.
pub fn scope_map<T, F>(workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    pool_metrics().items.inc(n as u64);
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    // Worker utilization: one timer per worker per call, not
                    // per item — the per-index loop stays allocation- and
                    // atomic-inc-free beyond the work-stealing counter.
                    let t0 = std::time::Instant::now();
                    let mut got: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(i)));
                    }
                    let busy = t0.elapsed().as_micros() as u64;
                    (got, busy)
                })
            })
            .collect();
        let mut busy_total = 0u64;
        for h in handles {
            let (got, busy) = h.join().expect("scoped worker panicked");
            busy_total += busy;
            for (i, v) in got {
                slots[i] = Some(v);
            }
        }
        pool_metrics().busy_us.inc(busy_total);
    });
    slots.into_iter().map(|s| s.unwrap()).collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map_indexed(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map_indexed(0, |_| 1);
        assert!(out.is_empty());
    }

    #[test]
    fn map_with_uneven_work() {
        let pool = ThreadPool::new(4);
        let out = pool.map_indexed(16, |i| {
            // Uneven cost per item.
            let mut acc = 0u64;
            for k in 0..(i * 10_000) {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, (j, _)) in out.iter().enumerate() {
            assert_eq!(i, *j);
        }
    }

    #[test]
    fn scope_map_matches_serial_for_any_worker_count() {
        let data: Vec<u64> = (0..97).map(|i| i * 3 + 1).collect();
        let serial: Vec<u64> = (0..data.len()).map(|i| data[i] * data[i]).collect();
        for workers in [1usize, 2, 3, 7, 16, 64] {
            // closure borrows `data` — the whole point of scope_map
            let out = scope_map(workers, data.len(), |i| data[i] * data[i]);
            assert_eq!(out, serial, "workers={workers}");
        }
    }

    #[test]
    fn scope_map_empty_and_single() {
        let out: Vec<u8> = scope_map(8, 0, |_| 1);
        assert!(out.is_empty());
        let out = scope_map(8, 1, |i| i + 10);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn default_workers_at_least_one() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn utilization_counters_advance() {
        let before = pool_metrics().items.get();
        let _ = scope_map(4, 32, |i| i);
        assert!(pool_metrics().items.get() >= before + 32);
        let jobs_before = pool_metrics().jobs.get();
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool);
        assert!(pool_metrics().jobs.get() >= jobs_before + 1);
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.map_indexed(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }
}
