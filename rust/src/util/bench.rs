//! Benchmark harness (the vendor set has no `criterion`).
//!
//! Provides warmup, calibrated iteration counts, and robust statistics
//! (median, mean, p95, MAD) for `cargo bench` targets declared with
//! `harness = false`. Output format is one line per benchmark:
//!
//! ```text
//! quant/nf4/pack            med   1.234 µs   mean   1.301 µs   p95   1.410 µs   (1000 iters)
//! ```

use std::time::{Duration, Instant};

/// Result statistics for one benchmark.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub mad_ns: f64,
    /// Optional throughput denominator (elements/bytes per iteration).
    pub elements_per_iter: Option<f64>,
}

impl Stats {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.elements_per_iter.map(|e| e / (self.median_ns * 1e-9))
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:8.1} ns")
    } else if ns < 1e6 {
        format!("{:8.3} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.3} ms", ns / 1e6)
    } else {
        format!("{:8.3} s ", ns / 1e9)
    }
}

fn fmt_rate(x: f64) -> String {
    if x >= 1e9 {
        format!("{:7.2} G/s", x / 1e9)
    } else if x >= 1e6 {
        format!("{:7.2} M/s", x / 1e6)
    } else if x >= 1e3 {
        format!("{:7.2} K/s", x / 1e3)
    } else {
        format!("{x:7.2} /s")
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} med {}   mean {}   p95 {}   ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )?;
        if let Some(tp) = self.throughput_per_sec() {
            write!(f, "   {}", fmt_rate(tp))?;
        }
        Ok(())
    }
}

/// Benchmark runner. Collects all results so a bench binary can print a
/// summary and optionally dump JSON for EXPERIMENTS.md.
pub struct Bencher {
    pub target_time: Duration,
    pub warmup_time: Duration,
    pub max_iters: usize,
    pub results: Vec<Stats>,
    /// Filter substring from AFQ_BENCH_FILTER / argv.
    pub filter: Option<String>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        let filter = std::env::args().nth(1).filter(|a| !a.starts_with('-'));
        // honour `cargo bench -- <filter>`: first non-flag arg
        let filter = filter.or_else(|| std::env::var("AFQ_BENCH_FILTER").ok());
        let quick = std::env::var("AFQ_BENCH_QUICK").is_ok();
        Self {
            target_time: if quick { Duration::from_millis(120) } else { Duration::from_millis(700) },
            warmup_time: if quick { Duration::from_millis(40) } else { Duration::from_millis(200) },
            max_iters: 1_000_000,
            results: Vec::new(),
            filter,
        }
    }

    /// Run one benchmark: `f` is the timed closure; it should return a value
    /// that is consumed by `std::hint::black_box` to prevent elision.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, f: F) -> Option<&Stats> {
        self.bench_with_elements(name, None, f)
    }

    /// As `bench`, with a throughput denominator (elements per iteration).
    pub fn bench_with_elements<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        elements_per_iter: Option<f64>,
        mut f: F,
    ) -> Option<&Stats> {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return None;
            }
        }
        // Warmup and per-iteration estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed() < self.warmup_time {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64).max(1.0);

        // Sample in batches: 30 samples, each batch sized so one batch ≈ target/30.
        let samples_wanted = 30usize;
        let batch = ((self.target_time.as_nanos() as f64 / samples_wanted as f64 / est_ns)
            .ceil() as usize)
            .clamp(1, self.max_iters);
        let mut samples = Vec::with_capacity(samples_wanted);
        let mut total_iters = 0usize;
        let bench_start = Instant::now();
        while samples.len() < samples_wanted && bench_start.elapsed() < self.target_time * 3 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
        let min = samples[0];
        let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = dev[dev.len() / 2];
        let stats = Stats {
            name: name.to_string(),
            iters: total_iters,
            median_ns: median,
            mean_ns: mean,
            p95_ns: p95,
            min_ns: min,
            mad_ns: mad,
            elements_per_iter,
        };
        println!("{stats}");
        self.results.push(stats);
        self.results.last()
    }

    /// Persist results as `results/BENCH_<name>.json` (see
    /// [`save_bench_doc`]). Returns the written path.
    pub fn save(&self, name: &str) -> std::io::Result<String> {
        save_bench_doc(name, self.to_json())
    }

    /// Dump results as JSON (used to archive bench runs in results/).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut arr = Vec::new();
        for s in &self.results {
            let mut o = Json::obj();
            o.set("name", Json::Str(s.name.clone()))
                .set("median_ns", Json::Num(s.median_ns))
                .set("mean_ns", Json::Num(s.mean_ns))
                .set("p95_ns", Json::Num(s.p95_ns))
                .set("min_ns", Json::Num(s.min_ns))
                .set("iters", Json::Num(s.iters as f64));
            if let Some(tp) = s.throughput_per_sec() {
                o.set("throughput_per_s", Json::Num(tp));
            }
            arr.push(o);
        }
        Json::Arr(arr)
    }
}

/// Write a bench payload as `results/BENCH_<name>.json` via
/// [`crate::util::write_file`] (which creates `results/` as needed). The
/// payload is one JSON object —
/// `{"bench": <name>, "results": [...], "metrics": {...}}` — so
/// downstream tooling can glob `BENCH_*.json` and key on the `bench`
/// field; `metrics` is the process's metrics-registry snapshot
/// ([`crate::obs::registry::snapshot_json`]) taken at save time, so every
/// archived bench run carries its counters (memo hits, fallbacks, pool
/// utilization) alongside the timings. Single owner of that envelope:
/// used by [`Bencher::save`] and by bench binaries that collect rows
/// without a `Bencher` (the serving sweep). Returns the written path.
pub fn save_bench_doc(name: &str, results: crate::util::json::Json) -> std::io::Result<String> {
    use crate::util::json::Json;
    let path = format!("results/BENCH_{name}.json");
    let mut doc = Json::obj();
    doc.set("bench", Json::Str(name.to_string()))
        .set("results", results)
        .set("metrics", crate::obs::registry::snapshot_json())
        // High-water mark of the decoded-panel cache over this process —
        // the `afq_panelcache_bytes` gauge only shows the instantaneous
        // value, so the envelope pins the peak a bench run actually paid.
        .set(
            "panelcache_peak_bytes",
            Json::Num(crate::quant::panelcache::peak_bytes() as f64),
        )
        // The SIMD dispatch level at save time, so archived runs are
        // comparable: `afq obs compare` treats rows from different levels
        // as informational, never a gate failure.
        .set(
            "simd_level",
            Json::Str(crate::util::simd::level().name().to_string()),
        );
    crate::util::write_file(&path, &doc.to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            target_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            max_iters: 1_000_000,
            results: Vec::new(),
            filter: None,
        };
        b.bench("noop-ish", || std::hint::black_box(1u64 + 1));
        let s = &b.results[0];
        assert!(s.median_ns > 0.0);
        assert!(s.median_ns < 1e6, "a trivial op should be <1ms: {}", s.median_ns);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.001);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bencher {
            target_time: Duration::from_millis(10),
            warmup_time: Duration::from_millis(2),
            max_iters: 1000,
            results: Vec::new(),
            filter: Some("match-me".into()),
        };
        assert!(b.bench("other", || 1).is_none());
        assert!(b.bench("match-me/x", || 1).is_some());
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "t".into(),
            iters: 1,
            median_ns: 1000.0, // 1 µs
            mean_ns: 1000.0,
            p95_ns: 1000.0,
            min_ns: 1000.0,
            mad_ns: 0.0,
            elements_per_iter: Some(1000.0),
        };
        // 1000 elements per µs = 1e9/s
        assert!((s.throughput_per_sec().unwrap() - 1e9).abs() < 1.0);
    }

    #[test]
    fn save_bench_doc_writes_envelope() {
        use crate::util::json::Json;
        let path = save_bench_doc("unit_test_tmp", Json::Arr(vec![Json::Num(1.0)])).unwrap();
        assert!(path.ends_with("BENCH_unit_test_tmp.json"));
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(), "unit_test_tmp");
        assert_eq!(back.at(&["results"]).unwrap().as_arr().unwrap().len(), 1);
        // The envelope always carries the panel-cache high-water mark
        // (0 when the cache never ran in this process).
        assert!(back.get("panelcache_peak_bytes").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_file(&path);
    }

    /// The envelope embeds a metrics-registry snapshot that survives a JSON
    /// round trip: registered counters come back under `"metrics"` with
    /// their exact values.
    #[test]
    fn save_bench_doc_embeds_metrics_snapshot() {
        use crate::util::json::Json;
        let c = crate::obs::registry::counter("afq_test_bench_embed_total");
        c.inc(7);
        let path = save_bench_doc("unit_test_metrics_tmp", Json::Arr(vec![])).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let got = back
            .at(&["metrics", "afq_test_bench_embed_total"])
            .and_then(|j| j.as_f64())
            .unwrap();
        assert!(got >= 7.0, "snapshot counter round-trips: {got}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn json_dump_contains_names() {
        let mut b = Bencher {
            target_time: Duration::from_millis(5),
            warmup_time: Duration::from_millis(1),
            max_iters: 1000,
            results: Vec::new(),
            filter: None,
        };
        b.bench("alpha", || 0u8);
        let j = b.to_json().to_string_compact();
        assert!(j.contains("alpha"));
        assert!(j.contains("median_ns"));
    }
}
