//! Deterministic pseudo-random number generation.
//!
//! The vendor set has no `rand` crate, so AFQ ships its own small,
//! well-tested generator: xoshiro256** (Blackman & Vigna) seeded through
//! SplitMix64, plus the distribution samplers the experiments need
//! (uniform, standard normal via the polar method, integer ranges).
//!
//! Determinism matters here: every experiment in `afq::exp` takes an
//! explicit seed so that figures are exactly reproducible run-to-run.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the main generator.
///
/// Period 2^256 − 1; passes BigCrush. All AFQ sampling goes through this.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the polar method.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, cached_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal deviate via the Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.cached_normal = Some(v * k);
                return u * k;
            }
        }
    }

    /// Normal deviate with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fill a slice with standard normal f32 samples.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Vector of n standard normal f32 samples.
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal_f32(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent generator (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.f64();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 400_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
            s3 += z * z * z;
            s4 += z * z * z * z;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s3 / nf).abs() < 0.03);
        assert!((s4 / nf - 3.0).abs() < 0.08);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent_enough() {
        let mut base = Rng::new(1234);
        let mut a = base.split();
        let mut b = base.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn normal_ms_shifts_and_scales() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.normal_ms(3.0, 0.5);
        }
        assert!((sum / n as f64 - 3.0).abs() < 0.01);
    }
}
